//! Uniformity-driven scalarization (tier-2 pass): consumes the
//! [`uniformity`] analysis to hoist warp-uniform work out of the vector
//! path, and exposes the per-kernel uniform/varying profile the runtime's
//! Tensix tile-mode heuristic keys on.
//!
//! hetIR has no explicit scalar/vector register split — the backends make
//! that assignment (the Tensix translator places uniform values in scalar
//! core registers; SIMT backends broadcast them per warp). What the
//! mid-end *can* do is schedule: within each straight-line run of pure
//! instructions, uniform (scalar-path) work is floated above varying
//! (vector-path) work, subject to data dependences. On the Tensix MIMD
//! backend that groups the scalar-core prefix of each block, so uniform
//! address/control arithmetic issues once instead of interleaving with
//! per-lane vector work; on SIMT backends it is a no-cost schedule.
//!
//! Determinism: only pure, non-team instructions move (`Ld` may move —
//! the run it moves within contains no store, atomic, fence, or barrier,
//! so the loaded bytes are identical), swaps respect every def/use
//! dependence, and no instruction crosses a barrier or control edge.
//! Register state at every barrier — and therefore every snapshot blob —
//! plus the modeled cost report (same instruction multiset, same
//! addresses) are bit-identical to the unscheduled kernel's.

use crate::hetir::instr::{Inst, Reg};
use crate::hetir::module::{Kernel, Stmt};
use crate::hetir::passes::uniformity::{self, Uniformity};

/// Per-kernel uniform/varying instruction counts (the runtime's Tensix
/// tile-mode heuristic consumes this; see `runtime::launch`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ScalarProfile {
    /// Instructions whose result (or, for resultless instructions, whose
    /// every input) is warp-uniform.
    pub uniform: usize,
    /// Instructions depending on thread identity.
    pub varying: usize,
}

impl ScalarProfile {
    /// True when at least `pct` percent of classified instructions are
    /// uniform (zero-instruction kernels are not "mostly uniform").
    pub fn mostly_uniform(&self, pct: usize) -> bool {
        let total = self.uniform + self.varying;
        total > 0 && self.uniform * 100 >= total * pct
    }
}

fn inst_is_uniform(i: &Inst, uni: &Uniformity, buf: &mut Vec<Reg>) -> bool {
    if let Some(d) = i.def() {
        return uni.is_uniform(d);
    }
    buf.clear();
    i.uses(buf);
    buf.iter().all(|r| uni.is_uniform(*r))
}

/// Classify every instruction of `k` as uniform or varying.
pub fn profile(k: &Kernel) -> ScalarProfile {
    let uni = uniformity::run(k);
    let mut p = ScalarProfile::default();
    let mut buf = Vec::new();
    k.visit_insts(|i| {
        if inst_is_uniform(i, &uni, &mut buf) {
            p.uniform += 1;
        } else {
            p.varying += 1;
        }
    });
    p
}

/// Whether an instruction may be re-scheduled within its run: pure (its
/// only effect is its def), thread-local, and not a barrier/fence.
fn movable(i: &Inst) -> bool {
    i.def().is_some() && !i.has_side_effect() && !i.is_team_op()
}

/// Stable uniform-first partition of one run of movable instructions.
/// A uniform instruction bubbles up past a varying neighbor only when
/// the pair is independent (no RAW/WAR/WAW hazard between them).
fn schedule_run(run: &mut [Stmt], uni: &Uniformity) {
    let n = run.len();
    let mut buf = Vec::new();
    let mut changed = true;
    while changed {
        changed = false;
        for j in 0..n.saturating_sub(1) {
            let (a, b) = (&run[j], &run[j + 1]);
            let (Stmt::I(ia), Stmt::I(ib)) = (a, b) else { continue };
            let (da, db) = (ia.def().unwrap(), ib.def().unwrap());
            if !uni.is_varying(da) || !uni.is_uniform(db) {
                continue;
            }
            // Dependence check: b must not read a's def (RAW), a must not
            // read b's def (WAR), and they must not write the same reg.
            if da == db {
                continue;
            }
            buf.clear();
            ib.uses(&mut buf);
            if buf.contains(&da) {
                continue;
            }
            buf.clear();
            ia.uses(&mut buf);
            if buf.contains(&db) {
                continue;
            }
            run.swap(j, j + 1);
            changed = true;
        }
    }
}

fn walk(stmts: &mut [Stmt], uni: &Uniformity) {
    let mut i = 0;
    while i < stmts.len() {
        if matches!(&stmts[i], Stmt::I(inst) if movable(inst)) {
            let start = i;
            while i < stmts.len() && matches!(&stmts[i], Stmt::I(inst) if movable(inst)) {
                i += 1;
            }
            schedule_run(&mut stmts[start..i], uni);
        } else {
            match &mut stmts[i] {
                Stmt::If { then_b, else_b, .. } => {
                    walk(then_b, uni);
                    walk(else_b, uni);
                }
                Stmt::While { cond, body, .. } => {
                    walk(cond, uni);
                    walk(body, uni);
                }
                _ => {}
            }
            i += 1;
        }
    }
}

/// Run the uniform-first scheduler over the kernel.
pub fn run(k: &mut Kernel) {
    let uni = uniformity::run(k);
    let mut body = std::mem::take(&mut k.body);
    walk(&mut body, &uni);
    k.body = body;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetir::builder::KernelBuilder;
    use crate::hetir::instr::{Address, BinOp, Dim, Operand, SpecialReg};
    use crate::hetir::types::{AddrSpace, Scalar, Type, Value};
    use crate::hetir::verify::verify_kernel;

    fn insts(k: &Kernel) -> Vec<Inst> {
        let mut v = Vec::new();
        k.visit_insts(|i| v.push(i.clone()));
        v
    }

    /// Varying work first, uniform work second → the scheduler floats the
    /// independent uniform chain above the varying chain.
    #[test]
    fn uniform_work_floats_above_varying_work() {
        let mut b = KernelBuilder::new("k");
        let x = b.param("x", Type::U32);
        let tid = b.special(SpecialReg::GlobalId(Dim::X));
        let v1 = b.bin(BinOp::Add, Scalar::U32, tid.into(), Operand::Imm(Value::u32(1)));
        let u1 = b.bin(BinOp::Add, Scalar::U32, x.into(), Operand::Imm(Value::u32(2)));
        let u2 = b.bin(BinOp::Mul, Scalar::U32, u1.into(), Operand::Imm(Value::u32(3)));
        let _v2 = b.bin(BinOp::Add, Scalar::U32, v1.into(), u2.into());
        let mut k = b.finish_raw();
        let before = insts(&k);
        run(&mut k);
        verify_kernel(&k).unwrap();
        let after = insts(&k);
        assert_eq!(before.len(), after.len());
        let pos = |dst: Reg, v: &[Inst]| {
            v.iter().position(|i| i.def() == Some(dst)).unwrap()
        };
        assert!(pos(u1, &after) < pos(v1, &after), "uniform add above varying add");
        assert!(pos(u2, &after) < pos(v1, &after), "uniform mul above varying add");
        assert!(pos(u1, &after) < pos(u2, &after), "uniform chain order kept");
        assert!(pos(tid, &after) > pos(u2, &after), "varying GlobalId sinks below uniforms");
    }

    /// A uniform instruction reading a varying def must not move above it.
    #[test]
    fn dependences_pin_the_schedule() {
        let mut b = KernelBuilder::new("k");
        let tid = b.special(SpecialReg::ThreadIdx(Dim::X));
        let v = b.ballot(tid.into()); // team op: immovable run boundary
        let u = b.bin(BinOp::And, Scalar::U32, v.into(), Operand::Imm(Value::u32(1)));
        let mut k = b.finish_raw();
        let before = insts(&k);
        run(&mut k);
        verify_kernel(&k).unwrap();
        assert_eq!(before, insts(&k), "nothing may cross a team op");
        let _ = u;
    }

    /// Stores, atomics, and barriers bound runs: nothing crosses them.
    #[test]
    fn side_effects_bound_runs() {
        let mut b = KernelBuilder::new("k");
        let p = b.param("p", Type::PTR_GLOBAL);
        let x = b.param("x", Type::U32);
        let tid = b.special(SpecialReg::GlobalId(Dim::X));
        let v1 = b.bin(BinOp::Add, Scalar::U32, tid.into(), Operand::Imm(Value::u32(1)));
        b.st(AddrSpace::Global, Scalar::U32, Address::base(p), v1.into());
        let u1 = b.bin(BinOp::Add, Scalar::U32, x.into(), Operand::Imm(Value::u32(2)));
        let mut k = b.finish_raw();
        run(&mut k);
        verify_kernel(&k).unwrap();
        let after = insts(&k);
        let st_pos = after.iter().position(|i| matches!(i, Inst::St { .. })).unwrap();
        let u1_pos = after.iter().position(|i| i.def() == Some(u1)).unwrap();
        assert!(u1_pos > st_pos, "uniform add must not cross the store");
    }

    /// The profile classifies a thread-indexed kernel as mostly varying
    /// and a parameter-only kernel as mostly uniform.
    #[test]
    fn profile_classifies_kernels() {
        let mut b = KernelBuilder::new("vary");
        let p = b.param("p", Type::PTR_GLOBAL);
        let tid = b.special(SpecialReg::GlobalId(Dim::X));
        let v = b.bin(BinOp::Mul, Scalar::U32, tid.into(), Operand::Imm(Value::u32(3)));
        b.st(AddrSpace::Global, Scalar::U32, Address::indexed(p, tid, 4), v.into());
        let k = b.finish_raw();
        let pv = profile(&k);
        assert!(pv.varying >= 3, "{pv:?}");
        assert!(!pv.mostly_uniform(90));

        let mut b = KernelBuilder::new("unif");
        let x = b.param("x", Type::U32);
        let a = b.bin(BinOp::Add, Scalar::U32, x.into(), Operand::Imm(Value::u32(1)));
        let _c = b.bin(BinOp::Mul, Scalar::U32, a.into(), x.into());
        let k = b.finish_raw();
        let pu = profile(&k);
        assert_eq!(pu.varying, 0, "{pu:?}");
        assert!(pu.mostly_uniform(90));
    }

    /// Scheduling preserves suspension metadata exactly.
    #[test]
    fn preserves_suspension_metadata() {
        let mut b = KernelBuilder::new("k");
        let x = b.param("x", Type::U32);
        let tid = b.special(SpecialReg::GlobalId(Dim::X));
        let _v = b.bin(BinOp::Add, Scalar::U32, tid.into(), Operand::Imm(Value::u32(1)));
        let _u = b.bin(BinOp::Add, Scalar::U32, x.into(), Operand::Imm(Value::u32(2)));
        b.bar();
        let _w = b.bin(BinOp::Add, Scalar::U32, tid.into(), x.into());
        let mut k = b.finish();
        let barriers = k.num_barriers;
        let sp = k.suspension_points.clone();
        run(&mut k);
        verify_kernel(&k).unwrap();
        assert_eq!(k.num_barriers, barriers);
        assert_eq!(k.suspension_points, sp);
    }
}
