//! Loop-invariant code motion (tier-2 pass): hoists pure, loop-invariant
//! computations out of `While` bodies into the enclosing block, so a hot
//! loop stops re-executing them every iteration.
//!
//! The hoist conditions are deliberately strict — tier-2 code must stay
//! **bit-identical** to tier-1 in memory effects and snapshot blobs
//! (including the zero-trip case, where a hoisted instruction runs once
//! although the original never ran):
//!
//! 1. The candidate sits at the *top level* of a loop body (never under a
//!    nested `If`: hoisting conditionally-executed code would speculate it).
//! 2. It is pure and thread-local: has a destination, no side effects, no
//!    team communication, and is not `Div`/`Rem` (those trap on zero — a
//!    hoist could introduce a fault a zero-trip loop never raised) and not
//!    `Ld` (loop stores/atomics may change memory between iterations).
//! 3. Every source register is defined nowhere inside the loop.
//! 4. The destination is defined exactly once in the whole kernel (the
//!    candidate), and every use of it sits inside this loop *after* the
//!    candidate (none in the loop condition, none before it in the body,
//!    none outside the loop). This pins zero-trip bit-identity: the value
//!    the hoisted instruction computes is only ever observed where the
//!    original would already have computed it — and it guarantees the
//!    destination is dead at every barrier *before* the candidate, so the
//!    tier-1 suspension-point live sets (which tier-2 reuses verbatim —
//!    see `optimize_tier2`) stay exact.
//!
//! Floats may be hoisted: the hoisted op computes the same value from the
//! same inputs (it is invariant), so no reassociation occurs. Runs to a
//! fixpoint, so invariant chains and nested loops hoist fully.

use crate::hetir::instr::{BinOp, Inst, Reg};
use crate::hetir::module::{Kernel, Stmt};
use std::collections::HashMap;

/// Per-register static def/use counts over the whole kernel.
fn global_counts(k: &Kernel) -> (HashMap<Reg, u32>, HashMap<Reg, u32>) {
    let mut defs = HashMap::new();
    let mut uses = HashMap::new();
    let mut buf = Vec::new();
    k.visit_insts(|i| {
        if let Some(d) = i.def() {
            *defs.entry(d).or_insert(0) += 1;
        }
        buf.clear();
        i.uses(&mut buf);
        for r in &buf {
            *uses.entry(*r).or_insert(0) += 1;
        }
    });
    (defs, uses)
}

fn count_in_stmts(stmts: &[Stmt], defs: &mut HashMap<Reg, u32>, uses: &mut HashMap<Reg, u32>) {
    let mut buf = Vec::new();
    for s in stmts {
        s.visit_insts(&mut |i| {
            if let Some(d) = i.def() {
                *defs.entry(d).or_insert(0) += 1;
            }
            buf.clear();
            i.uses(&mut buf);
            for r in &buf {
                *uses.entry(*r).or_insert(0) += 1;
            }
        });
    }
}

/// Whether `i` is eligible to move at all (independent of invariance).
fn movable(i: &Inst) -> bool {
    if i.def().is_none() || i.has_side_effect() || i.is_team_op() {
        return false;
    }
    match i {
        // Traps on a zero divisor: hoisting would speculate the fault.
        Inst::Bin { op: BinOp::Div | BinOp::Rem, .. } => false,
        // Memory may be written by the loop between iterations.
        Inst::Ld { .. } => false,
        _ => true,
    }
}

/// Find and perform one hoist anywhere in `stmts`; `true` if one moved.
fn hoist_one(
    stmts: &mut Vec<Stmt>,
    kernel_defs: &HashMap<Reg, u32>,
    kernel_uses: &HashMap<Reg, u32>,
) -> bool {
    let mut i = 0;
    while i < stmts.len() {
        let mut hoisted: Option<Stmt> = None;
        match &mut stmts[i] {
            Stmt::If { then_b, else_b, .. } => {
                if hoist_one(then_b, kernel_defs, kernel_uses)
                    || hoist_one(else_b, kernel_defs, kernel_uses)
                {
                    return true;
                }
            }
            Stmt::While { cond, body, .. } => {
                // Innermost first: a value hoisted out of an inner loop
                // becomes a candidate for the outer loop next round.
                if hoist_one(cond, kernel_defs, kernel_uses)
                    || hoist_one(body, kernel_defs, kernel_uses)
                {
                    return true;
                }
                if let Some(ci) = find_candidate(cond, body, kernel_defs, kernel_uses) {
                    hoisted = Some(body.remove(ci));
                }
            }
            _ => {}
        }
        if let Some(inst) = hoisted {
            stmts.insert(i, inst);
            return true;
        }
        i += 1;
    }
    false
}

/// Index of the first hoistable top-level instruction in `body`, per the
/// module-level conditions.
fn find_candidate(
    cond: &[Stmt],
    body: &[Stmt],
    kernel_defs: &HashMap<Reg, u32>,
    kernel_uses: &HashMap<Reg, u32>,
) -> Option<usize> {
    // Defs and uses inside this loop (cond + body, all nesting levels).
    let (mut loop_defs, mut loop_uses) = (HashMap::new(), HashMap::new());
    count_in_stmts(cond, &mut loop_defs, &mut loop_uses);
    count_in_stmts(body, &mut loop_defs, &mut loop_uses);

    let mut buf = Vec::new();
    for (ci, s) in body.iter().enumerate() {
        let Stmt::I(inst) = s else { continue };
        if !movable(inst) {
            continue;
        }
        let dst = inst.def().expect("movable implies def");
        // Single static assignment over the whole kernel.
        if kernel_defs.get(&dst).copied().unwrap_or(0) != 1 {
            continue;
        }
        // Operands invariant: no def of any source inside the loop.
        buf.clear();
        inst.uses(&mut buf);
        if buf.iter().any(|r| loop_defs.contains_key(r)) {
            continue;
        }
        // All uses of dst live inside this loop...
        if kernel_uses.get(&dst).copied().unwrap_or(0) != loop_uses.get(&dst).copied().unwrap_or(0)
        {
            continue;
        }
        // ...and none in the condition or before the candidate.
        let mut early = HashMap::new();
        let mut early_defs = HashMap::new();
        count_in_stmts(cond, &mut early_defs, &mut early);
        count_in_stmts(&body[..ci], &mut early_defs, &mut early);
        if early.contains_key(&dst) {
            continue;
        }
        return Some(ci);
    }
    None
}

/// Run loop-invariant code motion to a fixpoint.
pub fn run(k: &mut Kernel) {
    loop {
        let (defs, uses) = global_counts(k);
        let mut body = std::mem::take(&mut k.body);
        let moved = hoist_one(&mut body, &defs, &uses);
        k.body = body;
        if !moved {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetir::builder::KernelBuilder;
    use crate::hetir::instr::Operand;
    use crate::hetir::types::{Scalar, Type, Value};
    use crate::hetir::verify::verify_kernel;

    fn top_level_kinds(k: &Kernel) -> Vec<&'static str> {
        k.body
            .iter()
            .map(|s| match s {
                Stmt::I(_) => "inst",
                Stmt::While { .. } => "while",
                Stmt::If { .. } => "if",
                _ => "other",
            })
            .collect()
    }

    /// `x*3+7` inside the loop hoists (both instructions, as a chain).
    #[test]
    fn hoists_invariant_chain_out_of_loop() {
        let mut b = KernelBuilder::new("k");
        let x = b.param("x", Type::U32);
        let n = b.param("n", Type::U32);
        let acc = b.mov(Type::U32, Operand::Imm(Value::u32(0)));
        b.for_u32(Operand::Imm(Value::u32(0)), n.into(), 1, |b, _| {
            let t = b.bin(BinOp::Mul, Scalar::U32, x.into(), Operand::Imm(Value::u32(3)));
            let u = b.bin(BinOp::Add, Scalar::U32, t.into(), Operand::Imm(Value::u32(7)));
            b.bin_into(acc, BinOp::Add, Scalar::U32, acc.into(), u.into());
        });
        let mut k = b.finish_raw();
        let before = k.inst_count();
        run(&mut k);
        verify_kernel(&k).unwrap();
        assert_eq!(k.inst_count(), before, "LICM moves, never adds/removes");
        // The while body should be down to the loop-carried add (plus the
        // for_u32 induction update); mul and add-7 sit before the loop.
        let Some(Stmt::While { body, .. }) =
            k.body.iter().find(|s| matches!(s, Stmt::While { .. }))
        else {
            panic!("loop missing")
        };
        let mut mul_in_loop = false;
        for s in body {
            s.visit_insts(&mut |i| {
                if matches!(i, Inst::Bin { op: BinOp::Mul, .. }) {
                    mul_in_loop = true;
                }
            });
        }
        assert!(!mul_in_loop, "invariant mul must hoist out: {:?}", top_level_kinds(&k));
        let hoisted: Vec<_> = k
            .body
            .iter()
            .take_while(|s| matches!(s, Stmt::I(_)))
            .filter(|s| {
                matches!(s, Stmt::I(Inst::Bin { op: BinOp::Mul | BinOp::Add, .. }))
            })
            .count();
        assert!(hoisted >= 2, "mul and add-7 both hoisted");
    }

    /// Loop-carried values and their consumers must stay put.
    #[test]
    fn loop_carried_work_stays() {
        let mut b = KernelBuilder::new("k");
        let n = b.param("n", Type::U32);
        let acc = b.mov(Type::U32, Operand::Imm(Value::u32(0)));
        b.for_u32(Operand::Imm(Value::u32(0)), n.into(), 1, |b, i| {
            let t = b.bin(BinOp::Mul, Scalar::U32, i.into(), Operand::Imm(Value::u32(3)));
            b.bin_into(acc, BinOp::Add, Scalar::U32, acc.into(), t.into());
        });
        let mut k = b.finish_raw();
        run(&mut k);
        verify_kernel(&k).unwrap();
        let Some(Stmt::While { body, .. }) =
            k.body.iter().find(|s| matches!(s, Stmt::While { .. }))
        else {
            panic!("loop missing")
        };
        let mut mul_in_loop = false;
        for s in body {
            s.visit_insts(&mut |i| {
                if matches!(i, Inst::Bin { op: BinOp::Mul, .. }) {
                    mul_in_loop = true;
                }
            });
        }
        assert!(mul_in_loop, "induction-dependent mul must not hoist");
    }

    /// Division never hoists (zero-trip loop must not speculate a trap),
    /// and a value also used after the loop never hoists (zero-trip would
    /// change what the post-loop use observes).
    #[test]
    fn traps_and_escaping_values_not_hoisted() {
        let mut b = KernelBuilder::new("k");
        let x = b.param("x", Type::U32);
        let y = b.param("y", Type::U32);
        let n = b.param("n", Type::U32);
        let acc = b.mov(Type::U32, Operand::Imm(Value::u32(0)));
        let mut div = Reg(0);
        let mut escapee = Reg(0);
        b.for_u32(Operand::Imm(Value::u32(0)), n.into(), 1, |b, _| {
            div = b.bin(BinOp::Div, Scalar::U32, x.into(), y.into());
            escapee = b.bin(BinOp::Add, Scalar::U32, x.into(), Operand::Imm(Value::u32(1)));
            b.bin_into(acc, BinOp::Add, Scalar::U32, div.into(), escapee.into());
        });
        // Post-loop observer of `escapee` (reads stale value on zero trips).
        let _after = b.bin(BinOp::Add, Scalar::U32, escapee.into(), acc.into());
        let mut k = b.finish_raw();
        run(&mut k);
        verify_kernel(&k).unwrap();
        let Some(Stmt::While { body, .. }) =
            k.body.iter().find(|s| matches!(s, Stmt::While { .. }))
        else {
            panic!("loop missing")
        };
        let (mut div_in, mut esc_in) = (false, false);
        for s in body {
            s.visit_insts(&mut |i| match i {
                Inst::Bin { op: BinOp::Div, .. } => div_in = true,
                Inst::Bin { dst, .. } if *dst == escapee => esc_in = true,
                _ => {}
            });
        }
        assert!(div_in, "div must not be speculated");
        assert!(esc_in, "value used after the loop must not hoist");
    }

    /// Conditionally-executed instructions (under an If inside the loop)
    /// must not hoist.
    #[test]
    fn guarded_work_not_hoisted() {
        let mut b = KernelBuilder::new("k");
        let x = b.param("x", Type::U32);
        let p = b.param("p", Type::PRED);
        let n = b.param("n", Type::U32);
        let acc = b.mov(Type::U32, Operand::Imm(Value::u32(0)));
        let mut guarded = Reg(0);
        b.for_u32(Operand::Imm(Value::u32(0)), n.into(), 1, |b, _| {
            b.if_(p, |b| {
                guarded = b.bin(BinOp::Mul, Scalar::U32, x.into(), Operand::Imm(Value::u32(2)));
                b.bin_into(acc, BinOp::Add, Scalar::U32, acc.into(), guarded.into());
            });
        });
        let mut k = b.finish_raw();
        run(&mut k);
        verify_kernel(&k).unwrap();
        assert!(
            !k.body.iter().any(
                |s| matches!(s, Stmt::I(Inst::Bin { dst, .. }) if *dst == guarded)
            ),
            "guarded mul speculated out of loop"
        );
    }

    /// Barrier loops: hoisting must keep suspension metadata exact (the
    /// hoisted def is dead at every barrier before it ran in tier-1 too).
    #[test]
    fn preserves_suspension_metadata_in_barrier_loop() {
        let mut b = KernelBuilder::new("k");
        let x = b.param("x", Type::U32);
        let n = b.param("n", Type::U32);
        let acc = b.mov(Type::U32, Operand::Imm(Value::u32(0)));
        b.for_u32(Operand::Imm(Value::u32(0)), n.into(), 1, |b, _| {
            let t = b.bin(BinOp::Mul, Scalar::U32, x.into(), Operand::Imm(Value::u32(5)));
            b.bin_into(acc, BinOp::Add, Scalar::U32, acc.into(), t.into());
            b.bar();
        });
        let mut k = b.finish(); // segmenter + liveness
        let barriers = k.num_barriers;
        let sp = k.suspension_points.clone();
        run(&mut k);
        verify_kernel(&k).unwrap();
        assert_eq!(k.num_barriers, barriers);
        assert_eq!(k.suspension_points, sp);
        let Some(Stmt::While { body, .. }) =
            k.body.iter().find(|s| matches!(s, Stmt::While { .. }))
        else {
            panic!("loop missing")
        };
        let mut mul_in = false;
        for s in body {
            s.visit_insts(&mut |i| {
                if matches!(i, Inst::Bin { op: BinOp::Mul, .. }) {
                    mul_in = true;
                }
            });
        }
        assert!(!mul_in, "invariant mul should hoist past the barrier loop");
    }
}
