//! Target-agnostic hetIR passes.
//!
//! The compiler performs only device-independent transforms here (paper
//! §4.1: "we avoid any optimizations that assume specific hardware
//! characteristics ... those decisions are deferred to runtime or late
//! JIT"). The migration-critical passes are [`segmenter`] (stable barrier /
//! segment ids shared by every backend) and [`liveness`] (minimal snapshot
//! register sets).

pub mod constfold;
pub mod cse;
pub mod dce;
pub mod licm;
pub mod liveness;
pub mod scalarize;
pub mod segmenter;
pub mod strength;
pub mod uniformity;

use super::module::Kernel;

/// Run the standard optimization pipeline followed by the migration
/// metadata passes. Idempotent.
pub fn optimize(k: &mut Kernel) {
    constfold::run(k);
    cse::run(k);
    dce::run(k);
    // Re-establish migration metadata after any instruction removal.
    segmenter::run(k);
    liveness::run(k);
}

/// The optimizing tier-2 mid-end used by the background JIT compiler.
///
/// Runs on a kernel that already went through [`optimize`] at module
/// compile time. Deliberately does NOT rerun [`segmenter`] / [`liveness`]:
/// tier-1 suspension points, barrier ids, and captured register sets are
/// preserved verbatim so that tier-2 code produces bit-identical snapshot
/// blobs and a kernel paused under one tier resumes correctly under the
/// other. That is sound because every tier-2 pass keeps the value of every
/// register live at a barrier unchanged (strength rewrites are bit-exact
/// per the ALU's modular semantics, LICM only hoists values whose uses all
/// stay in the loop, scalarize only reorders independent pure instructions
/// within a barrier-free run) — the captured sets remain sound supersets.
/// Floats are never reassociated and journaled atomics never reordered.
pub fn optimize_tier2(k: &mut Kernel) {
    let barriers = k.num_barriers;
    let suspension = k.suspension_points.len();
    licm::run(k);
    strength::run(k);
    scalarize::run(k);
    debug_assert_eq!(k.num_barriers, barriers, "tier-2 must preserve barrier ids");
    debug_assert_eq!(
        k.suspension_points.len(),
        suspension,
        "tier-2 must preserve suspension metadata"
    );
}
