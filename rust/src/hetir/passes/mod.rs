//! Target-agnostic hetIR passes.
//!
//! The compiler performs only device-independent transforms here (paper
//! §4.1: "we avoid any optimizations that assume specific hardware
//! characteristics ... those decisions are deferred to runtime or late
//! JIT"). The migration-critical passes are [`segmenter`] (stable barrier /
//! segment ids shared by every backend) and [`liveness`] (minimal snapshot
//! register sets).

pub mod constfold;
pub mod cse;
pub mod dce;
pub mod liveness;
pub mod segmenter;
pub mod uniformity;

use super::module::Kernel;

/// Run the standard optimization pipeline followed by the migration
/// metadata passes. Idempotent.
pub fn optimize(k: &mut Kernel) {
    constfold::run(k);
    cse::run(k);
    dce::run(k);
    // Re-establish migration metadata after any instruction removal.
    segmenter::run(k);
    liveness::run(k);
}
