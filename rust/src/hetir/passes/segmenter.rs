//! Segmenter: assigns stable ids to every barrier in a kernel.
//!
//! Barrier ids double as migration segment boundaries (paper §4.2: "we
//! break the kernel into segments separated by barriers ... In migration,
//! we end the segment early on GPU A, transfer state, then start at the
//! next segment on GPU B"). Ids are assigned in deterministic pre-order
//! traversal so **every backend translation of the same kernel agrees on
//! them** — that agreement is what makes a snapshot taken on one
//! architecture restorable on another.

use crate::hetir::instr::Inst;
use crate::hetir::module::{Kernel, Stmt, SuspensionPoint};

fn walk(stmts: &mut [Stmt], next: &mut u32) {
    for s in stmts {
        match s {
            Stmt::I(Inst::Bar { id }) => {
                *id = *next;
                *next += 1;
            }
            Stmt::I(_) | Stmt::Break | Stmt::Continue | Stmt::Return => {}
            Stmt::If { then_b, else_b, .. } => {
                walk(then_b, next);
                walk(else_b, next);
            }
            Stmt::While { cond, body, .. } => {
                walk(cond, next);
                walk(body, next);
            }
        }
    }
}

/// Assign dense barrier ids in pre-order; reset suspension-point metadata.
pub fn run(k: &mut Kernel) {
    let mut next = 0u32;
    walk(&mut k.body, &mut next);
    k.num_barriers = next;
    k.suspension_points = (0..next)
        .map(|barrier_id| SuspensionPoint { barrier_id, live_regs: Vec::new() })
        .collect();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetir::instr::Reg;

    #[test]
    fn ids_are_dense_and_preorder() {
        let mut k = Kernel::new("k");
        k.reg_types.push(crate::hetir::types::Type::PRED);
        k.body = vec![
            Stmt::I(Inst::Bar { id: u32::MAX }),
            Stmt::If {
                cond: Reg(0),
                then_b: vec![Stmt::I(Inst::Bar { id: u32::MAX })],
                else_b: vec![Stmt::I(Inst::Bar { id: u32::MAX })],
            },
            Stmt::I(Inst::Bar { id: u32::MAX }),
        ];
        run(&mut k);
        assert_eq!(k.num_barriers, 4);
        let mut ids = vec![];
        k.visit_insts(|i| {
            if let Inst::Bar { id } = i {
                ids.push(*id)
            }
        });
        assert_eq!(ids, vec![0, 1, 2, 3]);
        assert_eq!(k.suspension_points.len(), 4);
    }

    #[test]
    fn rerun_is_stable() {
        let mut k = Kernel::new("k");
        k.body = vec![Stmt::I(Inst::Bar { id: u32::MAX }), Stmt::I(Inst::Bar { id: u32::MAX })];
        run(&mut k);
        let first: Vec<Stmt> = k.body.clone();
        run(&mut k);
        assert_eq!(k.body, first);
    }
}
