//! Uniformity (divergence) analysis.
//!
//! Classifies every virtual register as **uniform** (provably the same
//! value in all threads of a block) or **varying**. Two consumers:
//!
//! * The **Tensix backend** assigns uniform values to scalar RISC-V
//!   registers and varying values to 32-lane vector registers — the paper's
//!   "one core simulates a warp" mapping needs exactly this split.
//! * The **verifier** rejects barriers under divergent control flow (a
//!   block-wide barrier inside a branch only some threads take is undefined
//!   behaviour on every real GPU, and would deadlock our simulators).
//!
//! Sources of varying-ness: thread indices, loads from varying addresses,
//! atomics (each thread gets a different old value), shuffles, RNG state,
//! and any assignment under a divergent branch (control dependence).
//! Vote/ballot results are *uniform* — every lane receives the same value.

use crate::hetir::instr::{Inst, Reg, SpecialReg};
use crate::hetir::module::{Kernel, Stmt};
use std::collections::BTreeSet;

/// Analysis result.
#[derive(Debug, Clone, Default)]
pub struct Uniformity {
    varying: BTreeSet<Reg>,
}

impl Uniformity {
    pub fn is_varying(&self, r: Reg) -> bool {
        self.varying.contains(&r)
    }
    pub fn is_uniform(&self, r: Reg) -> bool {
        !self.is_varying(r)
    }
    /// Number of varying registers (diagnostics).
    pub fn varying_count(&self) -> usize {
        self.varying.len()
    }
}

struct Analysis {
    varying: BTreeSet<Reg>,
    changed: bool,
}

impl Analysis {
    fn mark(&mut self, r: Reg) {
        if self.varying.insert(r) {
            self.changed = true;
        }
    }

    fn operand_varying(&self, o: &crate::hetir::instr::Operand) -> bool {
        o.reg().map_or(false, |r| self.varying.contains(&r))
    }

    fn addr_varying(&self, a: &crate::hetir::instr::Address) -> bool {
        self.varying.contains(&a.base)
            || a.index.map_or(false, |r| self.varying.contains(&r))
    }

    fn inst(&mut self, i: &Inst, divergent: bool) {
        let dst = match i.def() {
            Some(d) => d,
            None => return,
        };
        let varying = match i {
            Inst::Special { kind, .. } => matches!(
                kind,
                SpecialReg::ThreadIdx(_) | SpecialReg::GlobalId(_)
            ),
            Inst::Mov { src, .. } => self.operand_varying(src),
            Inst::Bin { a, b, .. } | Inst::Cmp { a, b, .. } => {
                self.operand_varying(a) || self.operand_varying(b)
            }
            Inst::Un { a, .. } => self.operand_varying(a),
            Inst::Fma { a, b, c, .. } => {
                self.operand_varying(a) || self.operand_varying(b) || self.operand_varying(c)
            }
            Inst::Sel { cond, a, b, .. } => {
                self.operand_varying(cond) || self.operand_varying(a) || self.operand_varying(b)
            }
            Inst::Cvt { src, .. } => self.operand_varying(src),
            Inst::PtrAdd { addr, .. } => self.addr_varying(addr),
            // A load from a uniform address executed by all threads yields
            // the same value everywhere → uniform.
            Inst::Ld { addr, .. } => self.addr_varying(addr),
            // Each thread receives a distinct old value.
            Inst::Atom { .. } => true,
            // Every lane receives the identical reduction result.
            Inst::Vote { .. } | Inst::Ballot { .. } => false,
            Inst::Shfl { .. } => true,
            Inst::Rng { .. } => true,
            Inst::St { .. } | Inst::Bar { .. } | Inst::Fence { .. } | Inst::Trap { .. } => false,
        };
        if varying || divergent {
            self.mark(dst);
        }
    }

    fn block(&mut self, stmts: &[Stmt], divergent: bool) {
        for s in stmts {
            match s {
                Stmt::I(i) => self.inst(i, divergent),
                Stmt::If { cond, then_b, else_b } => {
                    let div = divergent || self.varying.contains(cond);
                    self.block(then_b, div);
                    self.block(else_b, div);
                }
                Stmt::While { cond, cond_reg, body } => {
                    // First process the condition block in the current
                    // context, then the body under (possible) divergence.
                    self.block(cond, divergent);
                    let div = divergent || self.varying.contains(cond_reg);
                    self.block(body, div);
                    // Re-run cond under divergence if the loop is divergent
                    // (a lane can exit earlier than others, making the
                    // condition computation itself control-dependent).
                    if div {
                        self.block(cond, true);
                    }
                }
                Stmt::Break | Stmt::Continue | Stmt::Return => {}
            }
        }
    }
}

/// Run the analysis to fixpoint.
pub fn run(k: &Kernel) -> Uniformity {
    let mut a = Analysis { varying: BTreeSet::new(), changed: true };
    while a.changed {
        a.changed = false;
        a.block(&k.body, false);
    }
    Uniformity { varying: a.varying }
}

/// Check whether any barrier sits under divergent control flow; returns the
/// offending barrier id if so. Used by the verifier.
pub fn barrier_under_divergence(k: &Kernel) -> Option<u32> {
    let u = run(k);
    fn walk(stmts: &[Stmt], u: &Uniformity, divergent: bool) -> Option<u32> {
        for s in stmts {
            match s {
                Stmt::I(Inst::Bar { id }) if divergent => return Some(*id),
                Stmt::I(_) | Stmt::Break | Stmt::Continue | Stmt::Return => {}
                Stmt::If { cond, then_b, else_b } => {
                    let div = divergent || u.is_varying(*cond);
                    if let Some(id) = walk(then_b, u, div) {
                        return Some(id);
                    }
                    if let Some(id) = walk(else_b, u, div) {
                        return Some(id);
                    }
                }
                Stmt::While { cond, cond_reg, body } => {
                    let div = divergent || u.is_varying(*cond_reg);
                    if let Some(id) = walk(cond, u, divergent) {
                        return Some(id);
                    }
                    if let Some(id) = walk(body, u, div) {
                        return Some(id);
                    }
                }
            }
        }
        None
    }
    walk(&k.body, &u, false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetir::builder::KernelBuilder;
    use crate::hetir::instr::*;
    use crate::hetir::types::{Scalar, Type, Value};

    #[test]
    fn thread_idx_is_varying_block_idx_uniform() {
        let mut b = KernelBuilder::new("k");
        let t = b.special(SpecialReg::ThreadIdx(Dim::X));
        let blk = b.special(SpecialReg::BlockIdx(Dim::X));
        let k = b.finish_raw();
        let u = run(&k);
        assert!(u.is_varying(t));
        assert!(u.is_uniform(blk));
    }

    #[test]
    fn varying_propagates_through_arith() {
        let mut b = KernelBuilder::new("k");
        let t = b.special(SpecialReg::ThreadIdx(Dim::X));
        let x = b.bin(BinOp::Add, Scalar::U32, t.into(), Operand::Imm(Value::u32(1)));
        let y = b.bin(
            BinOp::Add,
            Scalar::U32,
            Operand::Imm(Value::u32(1)),
            Operand::Imm(Value::u32(2)),
        );
        let k = b.finish_raw();
        let u = run(&k);
        assert!(u.is_varying(x));
        assert!(u.is_uniform(y));
    }

    #[test]
    fn control_dependence_marks_varying() {
        let mut b = KernelBuilder::new("k");
        let t = b.special(SpecialReg::ThreadIdx(Dim::X));
        let p = b.cmp(CmpOp::Lt, Scalar::U32, t.into(), Operand::Imm(Value::u32(16)));
        let x = b.mov(Type::U32, Operand::Imm(Value::u32(0)));
        b.if_(p, |b| {
            // constant assignment, but only some threads execute it
            b.bin_into(x, BinOp::Add, Scalar::U32, x.into(), Operand::Imm(Value::u32(1)));
        });
        let k = b.finish_raw();
        let u = run(&k);
        assert!(u.is_varying(x), "divergently-assigned register must be varying");
    }

    #[test]
    fn vote_result_is_uniform() {
        let mut b = KernelBuilder::new("k");
        let t = b.special(SpecialReg::ThreadIdx(Dim::X));
        let p = b.cmp(CmpOp::Lt, Scalar::U32, t.into(), Operand::Imm(Value::u32(16)));
        let v = b.vote(VoteKind::Any, p.into());
        let k = b.finish_raw();
        let u = run(&k);
        assert!(u.is_varying(p));
        assert!(u.is_uniform(v));
    }

    #[test]
    fn barrier_under_divergent_if_detected() {
        let mut b = KernelBuilder::new("k");
        let t = b.special(SpecialReg::ThreadIdx(Dim::X));
        let p = b.cmp(CmpOp::Lt, Scalar::U32, t.into(), Operand::Imm(Value::u32(16)));
        b.if_(p, |b| b.bar());
        let k = b.finish_raw();
        assert!(barrier_under_divergence(&k).is_some());
    }

    #[test]
    fn barrier_in_uniform_loop_ok() {
        let mut b = KernelBuilder::new("k");
        let n = b.param("N", Type::U32);
        b.for_u32(Operand::Imm(Value::u32(0)), n.into(), 1, |b, _| b.bar());
        let k = b.finish_raw();
        assert!(barrier_under_divergence(&k).is_none());
    }

    #[test]
    fn loop_carried_varying_reaches_fixpoint() {
        // x starts uniform but is updated from a varying value inside the
        // loop — after fixpoint it must be varying even in the condition.
        let mut b = KernelBuilder::new("k");
        let t = b.special(SpecialReg::ThreadIdx(Dim::X));
        let x = b.mov(Type::U32, Operand::Imm(Value::u32(0)));
        b.while_(
            |bb| bb.cmp(CmpOp::Lt, Scalar::U32, x.into(), Operand::Imm(Value::u32(10))),
            |bb| {
                bb.bin_into(x, BinOp::Add, Scalar::U32, x.into(), t.into());
            },
        );
        let k = b.finish_raw();
        let u = run(&k);
        assert!(u.is_varying(x));
    }
}
