//! Liveness analysis over the structured hetIR body.
//!
//! Computes, for every barrier (suspension point), the set of virtual
//! registers whose values must be captured into a snapshot for execution to
//! resume *after* that barrier — and nothing more (paper §8: "only saving
//! live registers (not entire register files) would help").
//!
//! The analysis is a standard backward may-liveness over the statement
//! tree. Loops iterate to a fixpoint (registers are finite and the transfer
//! function is monotone, so this terminates quickly). `Break`/`Continue`
//! take the live set of the innermost loop's exit/condition respectively,
//! carried on an explicit context stack.
//!
//! Over-approximation is safe here (a too-large snapshot is merely bigger);
//! under-approximation would corrupt migrated state, so tests in this
//! module and the cross-backend migration tests guard the precise sets.

use crate::hetir::instr::{Inst, Reg};
use crate::hetir::module::{Kernel, Stmt};
use std::collections::BTreeSet;

type Live = BTreeSet<Reg>;

/// Loop context for Break/Continue targets.
struct LoopCtx {
    live_exit: Live,
    live_cond_in: Live,
}

struct Analyzer {
    /// live_regs per barrier id, recorded as the live set *after* the
    /// barrier instruction (== what a resume at that segment must restore).
    at_barrier: Vec<Option<Live>>,
    loops: Vec<LoopCtx>,
}

impl Analyzer {
    fn transfer_inst(&mut self, i: &Inst, live: &mut Live) {
        if let Inst::Bar { id } = i {
            // Record live-after (current set, since we walk backward and
            // have already processed everything after the barrier).
            let slot = &mut self.at_barrier[*id as usize];
            match slot {
                // Loops visit barriers multiple times during fixpoint
                // iteration; keep the union (conservative).
                Some(prev) => prev.extend(live.iter().copied()),
                None => *slot = Some(live.clone()),
            }
        }
        if let Some(d) = i.def() {
            live.remove(&d);
        }
        let mut uses = Vec::new();
        i.uses(&mut uses);
        live.extend(uses);
    }

    /// Process a block backward: given live-out, return live-in.
    fn block(&mut self, stmts: &[Stmt], live_out: &Live) -> Live {
        let mut live = live_out.clone();
        for s in stmts.iter().rev() {
            match s {
                Stmt::I(i) => self.transfer_inst(i, &mut live),
                Stmt::Return => {
                    // Nothing after a Return in this thread is reachable;
                    // live set restarts from empty for code before it.
                    live = Live::new();
                }
                // Break/Continue outside a loop is malformed IR; the
                // verifier reports it — the analysis just stays safe.
                Stmt::Break => {
                    live = self.loops.last().map(|l| l.live_exit.clone()).unwrap_or_default();
                }
                Stmt::Continue => {
                    live =
                        self.loops.last().map(|l| l.live_cond_in.clone()).unwrap_or_default();
                }
                Stmt::If { cond, then_b, else_b } => {
                    let t = self.block(then_b, &live);
                    let e = self.block(else_b, &live);
                    live = &t | &e;
                    live.insert(*cond);
                }
                Stmt::While { cond, cond_reg, body } => {
                    // Fixpoint: live at condition entry depends on body
                    // live-in which depends back on condition entry.
                    let live_exit = live.clone();
                    let mut live_cond_in = Live::new();
                    loop {
                        self.loops.push(LoopCtx {
                            live_exit: live_exit.clone(),
                            live_cond_in: live_cond_in.clone(),
                        });
                        // after the test: either body runs (then back to
                        // cond) or we exit
                        let body_in = self.block(body, &live_cond_in);
                        let mut after_test = &body_in | &live_exit;
                        after_test.insert(*cond_reg);
                        let new_cond_in = self.block(cond, &after_test);
                        self.loops.pop();
                        if new_cond_in == live_cond_in {
                            break;
                        }
                        live_cond_in = new_cond_in;
                    }
                    live = live_cond_in;
                }
            }
        }
        live
    }
}

/// Run liveness; fills `kernel.suspension_points[*].live_regs`.
pub fn run(k: &mut Kernel) {
    if k.suspension_points.len() != k.num_barriers as usize {
        // Segmenter hasn't run (or IR changed); establish metadata first.
        super::segmenter::run(k);
    }
    let mut a = Analyzer {
        at_barrier: vec![None; k.num_barriers as usize],
        loops: Vec::new(),
    };
    let body = std::mem::take(&mut k.body);
    a.block(&body, &Live::new());
    k.body = body;
    for (id, live) in a.at_barrier.into_iter().enumerate() {
        k.suspension_points[id].live_regs = live.unwrap_or_default().into_iter().collect();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetir::builder::KernelBuilder;
    use crate::hetir::instr::*;
    use crate::hetir::types::{Scalar, Type, Value};

    /// A loop-carried accumulator must be live at a barrier inside the loop.
    #[test]
    fn loop_carried_reg_is_live_at_barrier() {
        let mut b = KernelBuilder::new("k");
        let n = b.param("N", Type::U32);
        let out = b.param("O", Type::PTR_GLOBAL);
        let acc = b.mov(Type::F32, Operand::Imm(Value::f32(0.0)));
        b.for_u32(Operand::Imm(Value::u32(0)), n.into(), 1, |b, _i| {
            b.bin_into(acc, BinOp::Add, Scalar::F32, acc.into(), Operand::Imm(Value::f32(1.0)));
            b.bar();
        });
        b.st(
            crate::hetir::types::AddrSpace::Global,
            Scalar::F32,
            Address::base(out),
            acc.into(),
        );
        let k = b.finish();
        let sp = k.suspension_point(0).unwrap();
        assert!(sp.live_regs.contains(&acc), "accumulator {acc} not in {:?}", sp.live_regs);
        assert!(sp.live_regs.contains(&n), "loop bound must be live");
        assert!(sp.live_regs.contains(&out), "output pointer must be live");
    }

    /// A register fully consumed before the barrier must NOT be captured.
    #[test]
    fn dead_reg_not_captured() {
        let mut b = KernelBuilder::new("k");
        let out = b.param("O", Type::PTR_GLOBAL);
        let t = b.bin(
            BinOp::Add,
            Scalar::F32,
            Operand::Imm(Value::f32(1.0)),
            Operand::Imm(Value::f32(2.0)),
        );
        b.st(crate::hetir::types::AddrSpace::Global, Scalar::F32, Address::base(out), t.into());
        b.bar();
        // after the barrier, only `out` is reused
        b.st(
            crate::hetir::types::AddrSpace::Global,
            Scalar::F32,
            Address::base(out).with_disp(4),
            Operand::Imm(Value::f32(0.0)),
        );
        let k = b.finish();
        let sp = k.suspension_point(0).unwrap();
        assert!(!sp.live_regs.contains(&t), "consumed temp must not be live");
        assert!(sp.live_regs.contains(&out));
    }

    /// Values defined after the barrier are not live at it.
    #[test]
    fn post_barrier_defs_not_live() {
        let mut b = KernelBuilder::new("k");
        let out = b.param("O", Type::PTR_GLOBAL);
        b.bar();
        let t = b.bin(
            BinOp::Add,
            Scalar::F32,
            Operand::Imm(Value::f32(1.0)),
            Operand::Imm(Value::f32(2.0)),
        );
        b.st(crate::hetir::types::AddrSpace::Global, Scalar::F32, Address::base(out), t.into());
        let k = b.finish();
        let sp = k.suspension_point(0).unwrap();
        assert!(!sp.live_regs.contains(&t));
    }

    /// Break takes the loop-exit live set.
    #[test]
    fn break_uses_exit_liveness() {
        let mut b = KernelBuilder::new("k");
        let out = b.param("O", Type::PTR_GLOBAL);
        let after_loop = b.mov(Type::F32, Operand::Imm(Value::f32(7.0)));
        let p = b.cmp(
            CmpOp::Lt,
            Scalar::U32,
            Operand::Imm(Value::u32(0)),
            Operand::Imm(Value::u32(1)),
        );
        b.while_(
            |_| p,
            |b| {
                b.bar();
                b.brk();
            },
        );
        b.st(
            crate::hetir::types::AddrSpace::Global,
            Scalar::F32,
            Address::base(out),
            after_loop.into(),
        );
        let k = b.finish();
        let sp = k.suspension_point(0).unwrap();
        assert!(
            sp.live_regs.contains(&after_loop),
            "value used after loop must be live at in-loop barrier before break"
        );
    }
}
