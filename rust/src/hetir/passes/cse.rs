//! Local common-subexpression elimination.
//!
//! Value-numbers pure instructions within straight-line regions and
//! rewrites recomputations into `Mov`s of the first occurrence (which DCE
//! then usually removes together with the producer if it dies). Only
//! thread-local, side-effect-free instructions participate: memory reads
//! are NOT eliminated (another thread may have written between them), and
//! team ops never move (every thread must execute them).
//!
//! Like the constant folder, the analysis is conservative at control-flow
//! joins and inside loops; soundness is covered by the differential
//! property tests (`tests/property.rs`).

use crate::hetir::instr::{Inst, Operand, Reg};
use crate::hetir::module::{Kernel, Stmt};
use std::collections::HashMap;

/// A hashable key describing a pure computation.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
enum Key {
    Special(String),
    Bin(u8, u8, OperandKey, OperandKey),
    Un(u8, u8, OperandKey),
    Fma(u8, OperandKey, OperandKey, OperandKey),
    Cmp(u8, u8, OperandKey, OperandKey),
    Sel(OperandKey, OperandKey, OperandKey),
    Cvt(u8, u8, OperandKey),
    PtrAdd(Reg, Option<Reg>, u32, i64),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum OperandKey {
    R(Reg),
    I(u64, u8),
}

fn okey(o: &Operand) -> OperandKey {
    match o {
        Operand::Reg(r) => OperandKey::R(*r),
        Operand::Imm(v) => OperandKey::I(v.bits, type_tag(v.ty)),
    }
}

fn type_tag(t: crate::hetir::types::Type) -> u8 {
    use crate::hetir::types::{AddrSpace, Scalar, Type};
    match t {
        Type::Scalar(Scalar::Pred) => 0,
        Type::Scalar(Scalar::I32) => 1,
        Type::Scalar(Scalar::U32) => 2,
        Type::Scalar(Scalar::I64) => 3,
        Type::Scalar(Scalar::U64) => 4,
        Type::Scalar(Scalar::F32) => 5,
        Type::Ptr(AddrSpace::Global) => 6,
        Type::Ptr(AddrSpace::Shared) => 7,
    }
}

fn key_of(i: &Inst) -> Option<Key> {
    Some(match i {
        Inst::Special { kind, .. } => Key::Special(format!("{kind:?}")),
        Inst::Bin { op, ty, a, b, .. } => {
            Key::Bin(*op as u8, ty.suffix().as_bytes()[0], okey(a), okey(b))
        }
        Inst::Un { op, ty, a, .. } => Key::Un(*op as u8, ty.suffix().as_bytes()[0], okey(a)),
        Inst::Fma { ty, a, b, c, .. } => {
            Key::Fma(ty.suffix().as_bytes()[0], okey(a), okey(b), okey(c))
        }
        Inst::Cmp { op, ty, a, b, .. } => {
            Key::Cmp(*op as u8, ty.suffix().as_bytes()[0], okey(a), okey(b))
        }
        Inst::Sel { cond, a, b, .. } => Key::Sel(okey(cond), okey(a), okey(b)),
        Inst::Cvt { from, to, src, .. } => {
            Key::Cvt(from.suffix().as_bytes()[0], to.suffix().as_bytes()[0], okey(src))
        }
        Inst::PtrAdd { addr, .. } => Key::PtrAdd(addr.base, addr.index, addr.scale, addr.disp),
        // Loads, atomics, team ops, RNG, barriers: never CSE'd.
        _ => return None,
    })
}

/// Registers an instruction's key depends on (for invalidation).
fn key_deps(i: &Inst, out: &mut Vec<Reg>) {
    i.uses(out);
}

struct Cse {
    replaced: usize,
}

impl Cse {
    fn block(&mut self, stmts: &mut [Stmt]) {
        // expr key -> register holding the value; reg -> keys depending on it
        let mut avail: HashMap<Key, Reg> = HashMap::new();
        let mut dep_of: HashMap<Reg, Vec<Key>> = HashMap::new();
        for s in stmts.iter_mut() {
            match s {
                Stmt::I(i) => {
                    let dst = i.def();
                    let key = key_of(i);
                    let hit = key.as_ref().and_then(|k| avail.get(k).copied());
                    if let (Some(prev), Some(d)) = (hit, dst) {
                        *i = Inst::Mov { dst: d, src: Operand::Reg(prev) };
                        self.replaced += 1;
                        // The Mov still redefines d: fall through to the
                        // invalidation below, then record d as an alias?
                        // (keep it simple: no aliasing.)
                        if let Some(keys) = dep_of.remove(&d) {
                            for k in keys {
                                avail.remove(&k);
                            }
                        }
                        avail.retain(|_, r| *r != d);
                        continue;
                    }
                    // Redefinition invalidates expressions over the old
                    // value and any expression held in the redefined
                    // register — BEFORE recording the new fact.
                    if let Some(d) = dst {
                        if let Some(keys) = dep_of.remove(&d) {
                            for k in keys {
                                avail.remove(&k);
                            }
                        }
                        avail.retain(|_, r| *r != d);
                    }
                    if let (Some(key), Some(d)) = (key, dst) {
                        avail.insert(key.clone(), d);
                        let mut deps = Vec::new();
                        key_deps(i, &mut deps);
                        for r in deps {
                            dep_of.entry(r).or_default().push(key.clone());
                        }
                    }
                }
                // Conservative: nothing survives into or across control flow.
                Stmt::If { then_b, else_b, .. } => {
                    self.block(then_b);
                    self.block(else_b);
                    avail.clear();
                    dep_of.clear();
                }
                Stmt::While { cond, body, .. } => {
                    self.block(cond);
                    self.block(body);
                    avail.clear();
                    dep_of.clear();
                }
                Stmt::Break | Stmt::Continue | Stmt::Return => {}
            }
        }
    }
}

/// Run local CSE; returns the number of replaced instructions.
pub fn run(k: &mut Kernel) -> usize {
    let mut c = Cse { replaced: 0 };
    let mut body = std::mem::take(&mut k.body);
    c.block(&mut body);
    k.body = body;
    c.replaced
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetir::builder::KernelBuilder;
    use crate::hetir::instr::*;
    use crate::hetir::types::{AddrSpace, Scalar, Type, Value};

    #[test]
    fn eliminates_duplicate_arith() {
        let mut b = KernelBuilder::new("k");
        let x = b.param("x", Type::U32);
        let a = b.bin(BinOp::Mul, Scalar::U32, x.into(), Operand::Imm(Value::u32(4)));
        let c = b.bin(BinOp::Mul, Scalar::U32, x.into(), Operand::Imm(Value::u32(4)));
        let _d = b.bin(BinOp::Add, Scalar::U32, a.into(), c.into());
        let mut k = b.finish_raw();
        assert_eq!(run(&mut k), 1);
        let mut movs = 0;
        k.visit_insts(|i| {
            if matches!(i, Inst::Mov { src: Operand::Reg(r), .. } if *r == a) {
                movs += 1;
            }
        });
        assert_eq!(movs, 1);
    }

    #[test]
    fn redefinition_invalidates() {
        let mut b = KernelBuilder::new("k");
        let x = b.param("x", Type::U32);
        let _a = b.bin(BinOp::Add, Scalar::U32, x.into(), Operand::Imm(Value::u32(1)));
        // redefine x
        b.bin_into(x, BinOp::Add, Scalar::U32, x.into(), Operand::Imm(Value::u32(5)));
        let _c = b.bin(BinOp::Add, Scalar::U32, x.into(), Operand::Imm(Value::u32(1)));
        let mut k = b.finish_raw();
        assert_eq!(run(&mut k), 0, "x changed between occurrences");
    }

    #[test]
    fn loads_never_csed() {
        let mut b = KernelBuilder::new("k");
        let p = b.param("p", Type::PTR_GLOBAL);
        let _v1 = b.ld(AddrSpace::Global, Scalar::F32, Address::base(p));
        let _v2 = b.ld(AddrSpace::Global, Scalar::F32, Address::base(p));
        let mut k = b.finish_raw();
        assert_eq!(run(&mut k), 0, "loads may observe other threads' writes");
    }

    #[test]
    fn team_ops_never_csed() {
        let mut b = KernelBuilder::new("k");
        let p = b.param("p", Type::PRED);
        let _v1 = b.vote(VoteKind::Any, p.into());
        let _v2 = b.vote(VoteKind::Any, p.into());
        let mut k = b.finish_raw();
        assert_eq!(run(&mut k), 0);
    }
}
