//! Constant folding: evaluates instructions whose operands are all
//! immediates and replaces them with `Mov` of the folded constant; also
//! forwards constants into later operand positions within straight-line
//! regions (a simple local constant propagation).
//!
//! Device-independent by construction — folding uses the same semantics the
//! simulators implement (see `sim::alu`), so a folded kernel and an
//! unfolded one produce bit-identical results on every backend. That
//! property is exercised by the differential tests in `tests/`.

use crate::hetir::instr::{BinOp, Inst, Operand, Reg};
use crate::hetir::module::{Kernel, Stmt};
use crate::hetir::types::{Scalar, Type, Value};
use crate::sim::alu;
use std::collections::HashMap;

/// Environment of known-constant registers (valid within one straight-line
/// region; invalidated at control-flow joins conservatively).
type Env = HashMap<Reg, Value>;

fn subst(op: &mut Operand, env: &Env) {
    if let Operand::Reg(r) = op {
        if let Some(v) = env.get(r) {
            *op = Operand::Imm(*v);
        }
    }
}

fn imm(op: &Operand) -> Option<Value> {
    match op {
        Operand::Imm(v) => Some(*v),
        Operand::Reg(_) => None,
    }
}

/// Try to fold one instruction; returns the constant result if it folds.
fn fold(i: &Inst) -> Option<(Reg, Value)> {
    match i {
        Inst::Mov { dst, src } => imm(src).map(|v| (*dst, v)),
        Inst::Bin { op, ty, dst, a, b } => {
            let (a, b) = (imm(a)?, imm(b)?);
            // Division/remainder by zero must fault at runtime, not fold.
            if matches!(op, BinOp::Div | BinOp::Rem) && ty.is_int() && b.bits == 0 {
                return None;
            }
            Some((*dst, alu::bin(*op, *ty, a, b).ok()?))
        }
        Inst::Un { op, ty, dst, a } => {
            let a = imm(a)?;
            Some((*dst, alu::un(*op, *ty, a).ok()?))
        }
        Inst::Cmp { op, ty, dst, a, b } => {
            let (a, b) = (imm(a)?, imm(b)?);
            Some((*dst, Value::pred(alu::cmp(*op, *ty, a, b))))
        }
        Inst::Cvt { from, to, dst, src } => {
            let v = imm(src)?;
            Some((*dst, alu::cvt(*from, *to, v)))
        }
        Inst::Sel { dst, cond, a, b } => {
            let c = imm(cond)?;
            let (a, b) = (imm(a)?, imm(b)?);
            Some((*dst, if c.as_pred() { a } else { b }))
        }
        Inst::Fma { ty: Scalar::F32, dst, a, b, c } => {
            let (a, b, c) = (imm(a)?, imm(b)?, imm(c)?);
            Some((*dst, Value::f32(a.as_f32().mul_add(b.as_f32(), c.as_f32()))))
        }
        _ => None,
    }
}

fn run_block(stmts: &mut Vec<Stmt>, env: &mut Env, k: &Kernel) {
    for s in stmts.iter_mut() {
        match s {
            Stmt::I(i) => {
                // Substitute known constants into operands first.
                match i {
                    Inst::Mov { src, .. } => subst(src, env),
                    Inst::Bin { a, b, .. } | Inst::Cmp { a, b, .. } => {
                        subst(a, env);
                        subst(b, env);
                    }
                    Inst::Un { a, .. } => subst(a, env),
                    Inst::Fma { a, b, c, .. } => {
                        subst(a, env);
                        subst(b, env);
                        subst(c, env);
                    }
                    Inst::Sel { cond, a, b, .. } => {
                        subst(cond, env);
                        subst(a, env);
                        subst(b, env);
                    }
                    Inst::Cvt { src, .. } => subst(src, env),
                    Inst::St { val, .. } => subst(val, env),
                    Inst::Atom { val, val2, .. } => {
                        subst(val, env);
                        if let Some(v2) = val2 {
                            subst(v2, env);
                        }
                    }
                    Inst::Vote { src, .. } | Inst::Ballot { src, .. } => subst(src, env),
                    Inst::Shfl { val, lane, .. } => {
                        subst(val, env);
                        subst(lane, env);
                    }
                    _ => {}
                }
                // Then fold if fully constant.
                if let Some((dst, v)) = fold(i) {
                    // Predicate registers can't hold arbitrary bit patterns.
                    debug_assert!(
                        k.reg_ty(dst) != Type::PRED || v.bits <= 1,
                        "folded non-boolean into predicate"
                    );
                    *i = Inst::Mov { dst, src: Operand::Imm(v) };
                    env.insert(dst, v);
                } else if let Some(d) = i.def() {
                    // Register redefined with non-constant value.
                    env.remove(&d);
                }
            }
            Stmt::If { then_b, else_b, .. } => {
                // Each branch starts from the current env; after the join we
                // conservatively drop constants defined inside either side.
                let mut t_env = env.clone();
                run_block(then_b, &mut t_env, k);
                let mut e_env = env.clone();
                run_block(else_b, &mut e_env, k);
                // Keep only facts that are identical on both paths AND were
                // already true before (simplest sound join).
                env.retain(|r, v| t_env.get(r) == Some(v) && e_env.get(r) == Some(v));
            }
            Stmt::While { cond, body, .. } => {
                // Registers assigned anywhere in the loop are not constant
                // at loop entry; clear them, then fold inside with that env.
                let mut killed = Vec::new();
                for b in [&*cond, &*body] {
                    for st in b {
                        st.visit_insts(&mut |ii| {
                            if let Some(d) = ii.def() {
                                killed.push(d);
                            }
                        });
                    }
                }
                for r in &killed {
                    env.remove(r);
                }
                let mut loop_env = env.clone();
                run_block(cond, &mut loop_env, k);
                run_block(body, &mut loop_env, k);
                // After the loop only pre-loop facts survive.
                for r in &killed {
                    env.remove(r);
                }
            }
            Stmt::Break | Stmt::Continue | Stmt::Return => {}
        }
    }
}

/// Run constant folding over the kernel.
pub fn run(k: &mut Kernel) {
    let mut env = Env::new();
    let mut body = std::mem::take(&mut k.body);
    run_block(&mut body, &mut env, k);
    k.body = body;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetir::types::Type;
    use crate::hetir::builder::KernelBuilder;

    #[test]
    fn folds_constant_chain() {
        let mut b = KernelBuilder::new("k");
        let x = b.mov(Type::U32, Operand::Imm(Value::u32(6)));
        let y = b.mov(Type::U32, Operand::Imm(Value::u32(7)));
        let z = b.bin(BinOp::Mul, Scalar::U32, x.into(), y.into());
        let _w = b.bin(BinOp::Add, Scalar::U32, z.into(), Operand::Imm(Value::u32(1)));
        let mut k = b.finish_raw();
        run(&mut k);
        // last instruction must now be Mov 43
        let mut last = None;
        k.visit_insts(|i| last = Some(i.clone()));
        match last.unwrap() {
            Inst::Mov { src: Operand::Imm(v), .. } => assert_eq!(v.as_u32(), 43),
            other => panic!("expected folded mov, got {other:?}"),
        }
    }

    #[test]
    fn does_not_fold_div_by_zero() {
        let mut b = KernelBuilder::new("k");
        let x = b.mov(Type::U32, Operand::Imm(Value::u32(1)));
        let _d = b.bin(BinOp::Div, Scalar::U32, x.into(), Operand::Imm(Value::u32(0)));
        let mut k = b.finish_raw();
        run(&mut k);
        let mut saw_div = false;
        k.visit_insts(|i| {
            if matches!(i, Inst::Bin { op: BinOp::Div, .. }) {
                saw_div = true;
            }
        });
        assert!(saw_div, "div by zero must be left to fault at runtime");
    }

    #[test]
    fn loop_carried_not_folded() {
        let mut b = KernelBuilder::new("k");
        let n = b.param("N", Type::U32);
        let acc = b.mov(Type::U32, Operand::Imm(Value::u32(0)));
        b.for_u32(Operand::Imm(Value::u32(0)), n.into(), 1, |b, _| {
            b.bin_into(acc, BinOp::Add, Scalar::U32, acc.into(), Operand::Imm(Value::u32(2)));
        });
        let use_after = b.bin(BinOp::Add, Scalar::U32, acc.into(), Operand::Imm(Value::u32(0)));
        let mut k = b.finish_raw();
        run(&mut k);
        // the add-after-loop must still reference acc, not a constant
        let mut ok = false;
        k.visit_insts(|i| {
            if let Inst::Bin { dst, a, .. } = i {
                if *dst == use_after {
                    ok = matches!(a, Operand::Reg(r) if *r == acc);
                }
            }
        });
        assert!(ok, "loop-carried register wrongly folded");
    }

    #[test]
    fn if_join_is_conservative() {
        let mut b = KernelBuilder::new("k");
        let p = b.param("p", Type::PRED);
        let x = b.mov(Type::U32, Operand::Imm(Value::u32(1)));
        b.if_else(
            p,
            |b| {
                b.bin_into(x, BinOp::Add, Scalar::U32, x.into(), Operand::Imm(Value::u32(1)));
            },
            |_b| {},
        );
        let y = b.bin(BinOp::Add, Scalar::U32, x.into(), Operand::Imm(Value::u32(0)));
        let mut k = b.finish_raw();
        run(&mut k);
        let mut ok = false;
        k.visit_insts(|i| {
            if let Inst::Bin { dst, a, .. } = i {
                if *dst == y {
                    ok = matches!(a, Operand::Reg(r) if *r == x);
                }
            }
        });
        assert!(ok, "divergently-assigned register wrongly folded");
    }
}
