//! Dead code elimination over the structured hetIR body.
//!
//! Removes instructions whose results are never used and which have no side
//! effects, plus empty `If` regions. Runs backward liveness internally (the
//! same machinery as `liveness.rs` but keeping a running live set while
//! deleting). Conservative around loops: anything defined in a loop that is
//! live at the loop's own entry survives.

use crate::hetir::instr::Reg;
use crate::hetir::module::{Kernel, Stmt};
use std::collections::BTreeSet;

type Live = BTreeSet<Reg>;

struct LoopCtx {
    live_exit: Live,
    live_cond_in: Live,
}

struct Dce {
    loops: Vec<LoopCtx>,
    removed: usize,
}

impl Dce {
    /// Process a block backward; deletes dead instructions in place.
    fn block(&mut self, stmts: &mut Vec<Stmt>, live_out: &Live) -> Live {
        let mut live = live_out.clone();
        let mut keep: Vec<bool> = vec![true; stmts.len()];
        for (idx, s) in stmts.iter_mut().enumerate().rev() {
            match s {
                Stmt::I(i) => {
                    let dead = !i.has_side_effect()
                        && !i.is_team_op()
                        && i.def().map_or(false, |d| !live.contains(&d));
                    if dead {
                        keep[idx] = false;
                        self.removed += 1;
                        continue;
                    }
                    if let Some(d) = i.def() {
                        live.remove(&d);
                    }
                    let mut uses = Vec::new();
                    i.uses(&mut uses);
                    live.extend(uses);
                }
                Stmt::Return => live = Live::new(),
                Stmt::Break => {
                    live = self.loops.last().map(|l| l.live_exit.clone()).unwrap_or_default()
                }
                Stmt::Continue => {
                    live =
                        self.loops.last().map(|l| l.live_cond_in.clone()).unwrap_or_default()
                }
                Stmt::If { cond, then_b, else_b } => {
                    let t = self.block(then_b, &live);
                    let e = self.block(else_b, &live);
                    if then_b.is_empty() && else_b.is_empty() {
                        keep[idx] = false;
                        self.removed += 1;
                        continue;
                    }
                    live = &t | &e;
                    live.insert(*cond);
                }
                Stmt::While { cond, cond_reg, body } => {
                    // Fixpoint as in liveness; DCE inside using the final
                    // live sets (delete only on the last iteration to stay
                    // sound while the fixpoint converges).
                    let live_exit = live.clone();
                    let mut live_cond_in = Live::new();
                    // First converge liveness without deleting.
                    loop {
                        self.loops.push(LoopCtx {
                            live_exit: live_exit.clone(),
                            live_cond_in: live_cond_in.clone(),
                        });
                        let body_in = probe_block(body, &live_cond_in, &mut self.loops);
                        let mut after_test = &body_in | &live_exit;
                        after_test.insert(*cond_reg);
                        let new_cond_in = probe_block(cond, &after_test, &mut self.loops);
                        self.loops.pop();
                        if new_cond_in == live_cond_in {
                            break;
                        }
                        live_cond_in = new_cond_in;
                    }
                    // Now delete with the converged sets.
                    self.loops.push(LoopCtx {
                        live_exit: live_exit.clone(),
                        live_cond_in: live_cond_in.clone(),
                    });
                    let body_in = self.block(body, &live_cond_in);
                    let mut after_test = &body_in | &live_exit;
                    after_test.insert(*cond_reg);
                    let cond_in = self.block(cond, &after_test);
                    self.loops.pop();
                    live = cond_in;
                }
            }
        }
        let mut it = keep.iter();
        stmts.retain(|_| *it.next().unwrap());
        live
    }
}

/// Liveness-only probe used while converging loop fixpoints (no deletion).
fn probe_block(stmts: &[Stmt], live_out: &Live, loops: &mut Vec<LoopCtx>) -> Live {
    let mut live = live_out.clone();
    for s in stmts.iter().rev() {
        match s {
            Stmt::I(i) => {
                if let Some(d) = i.def() {
                    live.remove(&d);
                }
                let mut uses = Vec::new();
                i.uses(&mut uses);
                live.extend(uses);
            }
            Stmt::Return => live = Live::new(),
            Stmt::Break => live = loops.last().map(|l| l.live_exit.clone()).unwrap_or_default(),
            Stmt::Continue => {
                live = loops.last().map(|l| l.live_cond_in.clone()).unwrap_or_default()
            }
            Stmt::If { cond, then_b, else_b } => {
                let t = probe_block(then_b, &live, loops);
                let e = probe_block(else_b, &live, loops);
                live = &t | &e;
                live.insert(*cond);
            }
            Stmt::While { cond, cond_reg, body } => {
                let live_exit = live.clone();
                let mut live_cond_in = Live::new();
                loop {
                    loops.push(LoopCtx {
                        live_exit: live_exit.clone(),
                        live_cond_in: live_cond_in.clone(),
                    });
                    let body_in = probe_block(body, &live_cond_in, loops);
                    let mut after_test = &body_in | &live_exit;
                    after_test.insert(*cond_reg);
                    let new_cond_in = probe_block(cond, &after_test, loops);
                    loops.pop();
                    if new_cond_in == live_cond_in {
                        break;
                    }
                    live_cond_in = new_cond_in;
                }
                live = live_cond_in;
            }
        }
    }
    live
}

/// Run DCE; returns the number of removed statements.
pub fn run(k: &mut Kernel) -> usize {
    let mut d = Dce { loops: Vec::new(), removed: 0 };
    let mut body = std::mem::take(&mut k.body);
    d.block(&mut body, &Live::new());
    k.body = body;
    d.removed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetir::builder::KernelBuilder;
    use crate::hetir::instr::*;
    use crate::hetir::types::{AddrSpace, Scalar, Type, Value};

    #[test]
    fn removes_unused_arith() {
        let mut b = KernelBuilder::new("k");
        let out = b.param("O", Type::PTR_GLOBAL);
        let used = b.bin(
            BinOp::Add,
            Scalar::F32,
            Operand::Imm(Value::f32(1.0)),
            Operand::Imm(Value::f32(2.0)),
        );
        let _dead = b.bin(
            BinOp::Mul,
            Scalar::F32,
            Operand::Imm(Value::f32(3.0)),
            Operand::Imm(Value::f32(4.0)),
        );
        b.st(AddrSpace::Global, Scalar::F32, Address::base(out), used.into());
        let mut k = b.finish_raw();
        let n_before = k.inst_count();
        let removed = run(&mut k);
        assert_eq!(removed, 1);
        assert_eq!(k.inst_count(), n_before - 1);
    }

    #[test]
    fn keeps_stores_and_atomics() {
        let mut b = KernelBuilder::new("k");
        let out = b.param("O", Type::PTR_GLOBAL);
        b.st(
            AddrSpace::Global,
            Scalar::F32,
            Address::base(out),
            Operand::Imm(Value::f32(1.0)),
        );
        let _old =
            b.atom(AtomOp::Add, AddrSpace::Global, Scalar::U32, Address::base(out), Operand::Imm(Value::u32(1)));
        let mut k = b.finish_raw();
        assert_eq!(run(&mut k), 0);
    }

    #[test]
    fn keeps_loop_carried_values() {
        let mut b = KernelBuilder::new("k");
        let out = b.param("O", Type::PTR_GLOBAL);
        let acc = b.mov(Type::U32, Operand::Imm(Value::u32(0)));
        b.for_u32(Operand::Imm(Value::u32(0)), Operand::Imm(Value::u32(10)), 1, |b, _| {
            b.bin_into(acc, BinOp::Add, Scalar::U32, acc.into(), Operand::Imm(Value::u32(1)));
        });
        b.st(AddrSpace::Global, Scalar::U32, Address::base(out), acc.into());
        let mut k = b.finish_raw();
        assert_eq!(run(&mut k), 0, "nothing in the loop is dead");
    }

    #[test]
    fn removes_empty_if() {
        let mut b = KernelBuilder::new("k");
        let p = b.param("p", Type::PRED);
        b.if_(p, |b| {
            // body computes something never used
            let _d = b.bin(
                BinOp::Add,
                Scalar::U32,
                Operand::Imm(Value::u32(1)),
                Operand::Imm(Value::u32(2)),
            );
        });
        let mut k = b.finish_raw();
        let removed = run(&mut k);
        assert_eq!(removed, 2); // the add, then the now-empty if
        assert!(k.body.is_empty());
    }

    #[test]
    fn team_ops_survive_even_if_unused() {
        // A vote participates in cross-thread communication; removing it on
        // one thread but not another would deadlock/diverge. DCE keeps it.
        let mut b = KernelBuilder::new("k");
        let p = b.param("p", Type::PRED);
        let _v = b.vote(VoteKind::Any, p.into());
        let mut k = b.finish_raw();
        assert_eq!(run(&mut k), 0);
    }
}
