//! Strength reduction (tier-2 pass): integer multiply / divide / remainder
//! by power-of-two constants become shifts and masks, and constant index
//! registers fold into address displacements.
//!
//! Every rewrite is a 1:1 instruction replacement whose result is
//! **bit-identical** to the original under the simulators' wrapping
//! semantics (`sim::alu`): `x * 2^k` ≡ `x << k` in two's-complement
//! modular arithmetic (signed or unsigned), and `x / 2^k` ≡ `x >> k`,
//! `x % 2^k` ≡ `x & (2^k - 1)` for **unsigned** types only (signed
//! division rounds toward zero, a shift rounds toward −∞ — never
//! rewritten). Floats are never touched (tier-2 determinism contract:
//! no reassociation). Because replacements are 1:1 and the cost model
//! charges ALU ops uniformly, the modeled `CostReport` of a
//! strength-reduced kernel is bit-identical to the original's.
//!
//! The address fold mirrors the simulators' effective-address rule
//! (`base + (idx_bits as i64).wrapping_mul(scale) + disp`, all wrapping),
//! so folding a known-constant index into `disp` is exact — including
//! for negative signed indices, whose register bit pattern is
//! zero-extended exactly like the fold's `bits as i64`.
//!
//! This pass changes no control structure, adds no registers, and
//! removes no instructions, so barrier ids and suspension-point live
//! sets remain valid as-is (see `optimize_tier2`).

use crate::hetir::instr::{Address, BinOp, Inst, Operand, Reg};
use crate::hetir::module::{Kernel, Stmt};
use crate::hetir::types::{Scalar, Type, Value};
use std::collections::HashMap;

/// Known-constant registers within a straight-line region (same
/// conservative joins as `constfold`).
type Env = HashMap<Reg, Value>;

/// The constant's exponent when the operand is an immediate power of two
/// in `ty`'s width (unsigned bit-pattern interpretation — modular
/// arithmetic makes that exact for `Mul` even on signed types).
fn pow2_exp(op: &Operand, ty: Scalar) -> Option<u32> {
    let v = match op {
        Operand::Imm(v) => *v,
        Operand::Reg(_) => return None,
    };
    let bits = if ty.is_64() { v.bits } else { v.bits & 0xFFFF_FFFF };
    (bits != 0 && bits & (bits - 1) == 0).then(|| bits.trailing_zeros())
}

fn is_zero(op: &Operand, ty: Scalar) -> bool {
    match op {
        Operand::Imm(v) => (if ty.is_64() { v.bits } else { v.bits & 0xFFFF_FFFF }) == 0,
        Operand::Reg(_) => false,
    }
}

/// An immediate of `ty` with the given bit pattern.
fn imm_of(ty: Scalar, bits: u64) -> Operand {
    let bits = if ty.is_64() { bits } else { bits & 0xFFFF_FFFF };
    Operand::Imm(Value { bits, ty: Type::Scalar(ty) })
}

/// Rewrite one instruction in place, if a cost-neutral reduction applies.
fn reduce(i: &mut Inst) {
    let Inst::Bin { op, ty, dst, a, b } = i else { return };
    if !ty.is_int() {
        return;
    }
    let (op, ty, dst, a, b) = (*op, *ty, *dst, *a, *b);
    match op {
        BinOp::Mul => {
            // Commutes: reduce whichever side is the power-of-two
            // constant. Skip all-immediate forms (constfold's job).
            let (k, other) = match (pow2_exp(&a, ty), pow2_exp(&b, ty)) {
                (_, Some(k)) if b.reg().is_none() && a.reg().is_some() => (Some(k), a),
                (Some(k), _) if a.reg().is_none() && b.reg().is_some() => (Some(k), b),
                _ => (None, a),
            };
            if let Some(k) = k {
                *i = if k == 0 {
                    Inst::Mov { dst, src: other }
                } else {
                    Inst::Bin { op: BinOp::Shl, ty, dst, a: other, b: imm_of(ty, k as u64) }
                };
            } else if (is_zero(&a, ty) && b.reg().is_some())
                || (is_zero(&b, ty) && a.reg().is_some())
            {
                *i = Inst::Mov { dst, src: imm_of(ty, 0) };
            }
        }
        // Unsigned only: signed division truncates toward zero, an
        // arithmetic shift would round toward −∞.
        BinOp::Div if !ty.is_signed() => {
            if let Some(k) = pow2_exp(&b, ty) {
                if a.reg().is_some() {
                    *i = if k == 0 {
                        Inst::Mov { dst, src: a }
                    } else {
                        Inst::Bin { op: BinOp::Shr, ty, dst, a, b: imm_of(ty, k as u64) }
                    };
                }
            }
        }
        BinOp::Rem if !ty.is_signed() => {
            if let Some(k) = pow2_exp(&b, ty) {
                if a.reg().is_some() {
                    *i = if k == 0 {
                        Inst::Mov { dst, src: imm_of(ty, 0) }
                    } else {
                        Inst::Bin {
                            op: BinOp::And,
                            ty,
                            dst,
                            a,
                            b: imm_of(ty, (1u64 << k) - 1),
                        }
                    };
                }
            }
        }
        _ => {}
    }
}

/// Fold a known-constant index register into the address displacement.
/// Exact by construction: the simulators compute
/// `base + (idx_bits as i64).wrapping_mul(scale) + disp` with wrapping
/// adds, and wrapping addition is associative.
fn fold_addr(a: &mut Address, env: &Env) {
    let Some(idx) = a.index else { return };
    let Some(v) = env.get(&idx) else { return };
    a.disp = a.disp.wrapping_add((v.bits as i64).wrapping_mul(a.scale as i64));
    a.index = None;
}

fn run_block(stmts: &mut [Stmt], env: &mut Env) {
    for s in stmts.iter_mut() {
        match s {
            Stmt::I(i) => {
                reduce(i);
                match i {
                    Inst::Ld { addr, .. } | Inst::St { addr, .. } | Inst::Atom { addr, .. } => {
                        fold_addr(addr, env)
                    }
                    Inst::PtrAdd { addr, .. } => fold_addr(addr, env),
                    _ => {}
                }
                match i {
                    Inst::Mov { dst, src: Operand::Imm(v) } => {
                        env.insert(*dst, *v);
                    }
                    _ => {
                        if let Some(d) = i.def() {
                            env.remove(&d);
                        }
                    }
                }
            }
            Stmt::If { then_b, else_b, .. } => {
                let mut t_env = env.clone();
                run_block(then_b, &mut t_env);
                let mut e_env = env.clone();
                run_block(else_b, &mut e_env);
                env.retain(|r, v| t_env.get(r) == Some(v) && e_env.get(r) == Some(v));
            }
            Stmt::While { cond, body, .. } => {
                let mut killed = Vec::new();
                for b in [&*cond, &*body] {
                    for st in b {
                        st.visit_insts(&mut |ii| {
                            if let Some(d) = ii.def() {
                                killed.push(d);
                            }
                        });
                    }
                }
                for r in &killed {
                    env.remove(r);
                }
                let mut loop_env = env.clone();
                run_block(cond, &mut loop_env);
                run_block(body, &mut loop_env);
                for r in &killed {
                    env.remove(r);
                }
            }
            Stmt::Break | Stmt::Continue | Stmt::Return => {}
        }
    }
}

/// Run strength reduction over the kernel.
pub fn run(k: &mut Kernel) {
    let mut env = Env::new();
    let mut body = std::mem::take(&mut k.body);
    run_block(&mut body, &mut env);
    k.body = body;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetir::builder::KernelBuilder;
    use crate::hetir::instr::AtomOp;
    use crate::hetir::types::AddrSpace;
    use crate::hetir::verify::verify_kernel;

    fn insts(k: &Kernel) -> Vec<Inst> {
        let mut v = Vec::new();
        k.visit_insts(|i| v.push(i.clone()));
        v
    }

    #[test]
    fn mul_div_rem_by_pow2_become_shift_and_mask() {
        let mut b = KernelBuilder::new("k");
        let x = b.param("x", Type::U32);
        let m = b.bin(BinOp::Mul, Scalar::U32, x.into(), Operand::Imm(Value::u32(8)));
        let d = b.bin(BinOp::Div, Scalar::U32, m.into(), Operand::Imm(Value::u32(4)));
        let _r = b.bin(BinOp::Rem, Scalar::U32, d.into(), Operand::Imm(Value::u32(16)));
        let mut k = b.finish_raw();
        run(&mut k);
        verify_kernel(&k).unwrap();
        let got = insts(&k);
        assert!(matches!(
            got[0],
            Inst::Bin { op: BinOp::Shl, a: Operand::Reg(r), b: Operand::Imm(v), .. }
                if r == x && v.bits == 3
        ));
        assert!(matches!(
            got[1],
            Inst::Bin { op: BinOp::Shr, b: Operand::Imm(v), .. } if v.bits == 2
        ));
        assert!(matches!(
            got[2],
            Inst::Bin { op: BinOp::And, b: Operand::Imm(v), .. } if v.bits == 15
        ));
    }

    #[test]
    fn mul_commutes_and_identities_fold() {
        let mut b = KernelBuilder::new("k");
        let x = b.param("x", Type::U32);
        let _a = b.bin(BinOp::Mul, Scalar::U32, Operand::Imm(Value::u32(16)), x.into());
        let _one = b.bin(BinOp::Mul, Scalar::U32, x.into(), Operand::Imm(Value::u32(1)));
        let _zero = b.bin(BinOp::Mul, Scalar::U32, x.into(), Operand::Imm(Value::u32(0)));
        let mut k = b.finish_raw();
        run(&mut k);
        verify_kernel(&k).unwrap();
        let got = insts(&k);
        assert!(matches!(
            got[0],
            Inst::Bin { op: BinOp::Shl, a: Operand::Reg(r), b: Operand::Imm(v), .. }
                if r == x && v.bits == 4
        ));
        assert!(matches!(got[1], Inst::Mov { src: Operand::Reg(r), .. } if r == x));
        assert!(matches!(got[2], Inst::Mov { src: Operand::Imm(v), .. } if v.bits == 0));
    }

    #[test]
    fn signed_div_and_non_pow2_left_alone() {
        let mut b = KernelBuilder::new("k");
        let x = b.param("x", Type::I32);
        let y = b.param("y", Type::U32);
        // Signed division must NOT become an arithmetic shift
        // (rounding direction differs for negative dividends).
        let _sd = b.bin(BinOp::Div, Scalar::I32, x.into(), Operand::Imm(Value::i32(4)));
        let _np = b.bin(BinOp::Mul, Scalar::U32, y.into(), Operand::Imm(Value::u32(40503)));
        // Signed Mul by a pow2 IS safe under wrapping semantics.
        let _sm = b.bin(BinOp::Mul, Scalar::I32, x.into(), Operand::Imm(Value::i32(4)));
        let mut k = b.finish_raw();
        run(&mut k);
        verify_kernel(&k).unwrap();
        let got = insts(&k);
        assert!(matches!(got[0], Inst::Bin { op: BinOp::Div, .. }));
        assert!(matches!(got[1], Inst::Bin { op: BinOp::Mul, .. }));
        assert!(matches!(got[2], Inst::Bin { op: BinOp::Shl, .. }));
    }

    #[test]
    fn constant_index_folds_into_displacement() {
        let mut b = KernelBuilder::new("k");
        let p = b.param("p", Type::PTR_GLOBAL);
        let idx = b.mov(Type::U32, Operand::Imm(Value::u32(5)));
        let v = b.ld(AddrSpace::Global, Scalar::U32, Address::indexed(p, idx, 4));
        b.st(
            AddrSpace::Global,
            Scalar::U32,
            Address::indexed(p, idx, 4).with_disp(64),
            v.into(),
        );
        b.atom(
            AtomOp::Add,
            AddrSpace::Global,
            Scalar::U32,
            Address::indexed(p, idx, 8),
            Operand::Imm(Value::u32(1)),
        );
        let mut k = b.finish_raw();
        run(&mut k);
        verify_kernel(&k).unwrap();
        let got = insts(&k);
        assert!(matches!(got[1], Inst::Ld { addr: Address { index: None, disp: 20, .. }, .. }));
        assert!(matches!(got[2], Inst::St { addr: Address { index: None, disp: 84, .. }, .. }));
        assert!(matches!(got[3], Inst::Atom { addr: Address { index: None, disp: 40, .. }, .. }));
    }

    #[test]
    fn divergently_assigned_index_not_folded() {
        let mut b = KernelBuilder::new("k");
        let p = b.param("p", Type::PTR_GLOBAL);
        let c = b.param("c", Type::PRED);
        let idx = b.mov(Type::U32, Operand::Imm(Value::u32(1)));
        b.if_(c, |b| {
            b.bin_into(idx, BinOp::Add, Scalar::U32, idx.into(), Operand::Imm(Value::u32(1)));
        });
        let _v = b.ld(AddrSpace::Global, Scalar::U32, Address::indexed(p, idx, 4));
        let mut k = b.finish_raw();
        run(&mut k);
        verify_kernel(&k).unwrap();
        let got = insts(&k);
        let ld = got.iter().find(|i| matches!(i, Inst::Ld { .. })).unwrap();
        assert!(
            matches!(ld, Inst::Ld { addr: Address { index: Some(r), .. }, .. } if *r == idx),
            "index assigned under divergence must not fold"
        );
    }

    #[test]
    fn preserves_structure_and_suspension_metadata() {
        let mut b = KernelBuilder::new("k");
        let x = b.param("x", Type::U32);
        let n = b.param("n", Type::U32);
        b.for_u32(Operand::Imm(Value::u32(0)), n.into(), 1, |b, _| {
            b.bin(BinOp::Mul, Scalar::U32, x.into(), Operand::Imm(Value::u32(4)));
            b.bar();
        });
        let mut k = b.finish(); // segmenter + liveness run
        let barriers = k.num_barriers;
        let sp = k.suspension_points.clone();
        let count = k.inst_count();
        run(&mut k);
        verify_kernel(&k).unwrap();
        assert_eq!(k.num_barriers, barriers);
        assert_eq!(k.suspension_points, sp);
        assert_eq!(k.inst_count(), count, "strength reduction must be 1:1");
    }
}
