//! hetIR — the portable GPU intermediate representation (paper §4.1).
//!
//! An architecture-neutral, SPMD, structured-control-flow IR with explicit
//! barriers and virtualized team operations. This module provides the IR
//! data structures, a programmatic [`builder`], the text-assembly
//! [`printer`]/[`parser`] pair (the on-disk "binary" format), a [`verify`]
//! pass, and the target-agnostic optimization + migration-metadata
//! [`passes`].

pub mod analyze;
pub mod builder;
pub mod instr;
pub mod module;
pub mod parser;
pub mod passes;
pub mod printer;
pub mod types;
pub mod verify;

pub use instr::{Address, BinOp, CmpOp, Dim, Inst, Operand, Reg, SpecialReg};
pub use module::{Kernel, Module, Stmt};
pub use types::{AddrSpace, Scalar, Type, Value};
