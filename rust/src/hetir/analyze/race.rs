//! Shared-memory race detection over barrier intervals (DESIGN.md §12).
//!
//! Two accesses can race iff (a) they live in the same barrier interval —
//! same canonical label, or labels joined by a loop backedge — and (b)
//! their byte ranges can overlap for **two distinct threads** of one
//! block. Overlap is decided on the affine offset forms: each access is
//! instantiated for one of two thread instances (renaming `tid.*` and
//! per-thread loop variables apart, sharing uniform symbols), and the
//! difference of the two offsets is bounded under both instances' path
//! guards. A proof that the ranges cannot meet ⇒ clean; anything short of
//! a proof ⇒ a `Warning` diagnostic (races are report-only, never a
//! launch gate — see `AnalysisLevel`).

use super::affine::{le_forms, lower_bound, upper_bound, Affine, Guard, Itv, Sym, POS_INF};
use super::{Access, AccessKind, Diagnostic, KernelReport, Prov, Severity};
use crate::hetir::types::AddrSpace;
use std::collections::{BTreeMap, HashSet};

/// Guard-substitution depth for race queries: pair queries combine two
/// guard sets, so allow a little more elimination than the default.
const DEPTH: u32 = 4;

/// A symbol instantiated for a two-thread race query: either shared
/// between both thread instances (uniform values, launch geometry,
/// params) or private to instance 0/1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum RSym {
    Sh(Sym),
    Inst(u8, Sym),
}

impl RSym {
    fn base(self) -> Sym {
        match self {
            RSym::Sh(s) | RSym::Inst(_, s) => s,
        }
    }
}

/// Which per-thread symbols get renamed apart for a query.
#[derive(Clone, Copy)]
enum Renaming<'a> {
    /// Same barrier interval, same loop iteration: `tid` and *varying*
    /// loop variables differ between the instances; uniform loop
    /// variables are lockstep-shared.
    SameInterval,
    /// Tail-of-iteration vs. head-of-next-iteration of loop `l`: loop
    /// variables minted by `l` or any nested loop also differ between the
    /// instances even when uniform (they belong to different iterations).
    Backedge { l: u32, kr: &'a KernelReport },
}

impl<'a> Renaming<'a> {
    fn apply(&self, kr: &KernelReport, inst: u8, s: Sym) -> RSym {
        let renamed = match s {
            Sym::Tid(_) => true,
            Sym::Opaque(q) => {
                let info = &kr.opaques[q as usize];
                !info.uniform
                    || match self {
                        Renaming::SameInterval => false,
                        Renaming::Backedge { l, kr } => loop_within(kr, info.loop_id, *l),
                    }
            }
            _ => false,
        };
        if renamed {
            RSym::Inst(inst, s)
        } else {
            RSym::Sh(s)
        }
    }
}

/// True if `inner` is `outer` or nested (transitively) inside it.
fn loop_within(kr: &KernelReport, inner: u32, outer: u32) -> bool {
    let mut cur = Some(inner);
    while let Some(l) = cur {
        if l == outer {
            return true;
        }
        cur = kr.loop_parent.get(l as usize).copied().flatten();
    }
    false
}

fn conflicting(a: AccessKind, b: AccessKind) -> bool {
    // Read/read never conflicts; atomic/atomic serializes by definition.
    !matches!(
        (a, b),
        (AccessKind::Read, AccessKind::Read) | (AccessKind::Atomic, AccessKind::Atomic)
    )
}

/// Run race detection over a kernel's recorded accesses, appending
/// `Warning` diagnostics for every pair that cannot be proven disjoint.
pub(crate) fn check(kr: &mut KernelReport) {
    let shared: Vec<usize> = (0..kr.accesses.len())
        .filter(|&i| kr.accesses[i].space == AddrSpace::Shared)
        .collect();
    if shared.is_empty() {
        return;
    }
    let mut reported: HashSet<(String, String)> = HashSet::new();
    let mut diags: Vec<Diagnostic> = Vec::new();

    for (pi, &i) in shared.iter().enumerate() {
        for &j in &shared[pi..] {
            let a = &kr.accesses[i];
            let b = &kr.accesses[j];
            if !conflicting(a.kind, b.kind) {
                continue;
            }
            let mut racy = false;
            if a.label == b.label && may_race(kr, a, b, Renaming::SameInterval) {
                racy = true;
            }
            if !racy {
                for &(t, h, l) in &kr.backedges {
                    let pair = if a.label == t && b.label == h {
                        Some((a, b))
                    } else if b.label == t && a.label == h {
                        Some((b, a))
                    } else {
                        None
                    };
                    if let Some((tail, head)) = pair {
                        if may_race(kr, tail, head, Renaming::Backedge { l, kr }) {
                            racy = true;
                            break;
                        }
                    }
                }
            }
            if racy {
                let (pa, pb) = (a.path.to_string(), b.path.to_string());
                let key = if pa <= pb { (pa, pb) } else { (pb, pa) };
                if reported.insert(key) {
                    diags.push(race_diag(kr, a, b));
                }
            }
        }
    }
    kr.diags.extend(diags);
}

fn race_diag(kr: &KernelReport, a: &Access, b: &Access) -> Diagnostic {
    let message = if a.path == b.path {
        format!(
            "possible shared-memory race: {} of `{}` can touch the same \
             bytes from two threads in one barrier interval",
            a.kind.verb(),
            a.off
        )
    } else {
        format!(
            "possible shared-memory race: {} of `{}` and {} of `{}` (at {}) \
             can overlap in one barrier interval",
            a.kind.verb(),
            a.off,
            b.kind.verb(),
            b.off,
            b.path
        )
    };
    Diagnostic {
        severity: Severity::Warning,
        kernel: kr.name.clone(),
        path: a.path.clone(),
        analysis: "race",
        message,
    }
}

/// Can accesses `a` (instance 0) and `b` (instance 1) overlap for two
/// distinct threads? `true` = could not prove disjoint.
fn may_race(kr: &KernelReport, a: &Access, b: &Access, ren: Renaming) -> bool {
    if a.prov == Prov::Unknown || b.prov == Prov::Unknown {
        return true; // untraceable base: overlaps everything in its space
    }

    let fa = a.off.map_syms(|s| ren.apply(kr, 0, s));
    let fb = b.off.map_syms(|s| ren.apply(kr, 1, s));
    let d = fb.sub(&fa);
    let (wa, wb) = (a.width as i128, b.width as i128);

    // Fast path for exact tid-strided forms: difference reduces to
    // `k + Σ c_d·(tidB_d − tidA_d)` with the dims covering every tid
    // dimension the kernel reads, so thread distinctness directly bounds
    // |difference| away from zero.
    if a.slop == Itv::ZERO && b.slop == Itv::ZERO && digit_disjoint(kr, &d, wa.max(wb)) {
        return false;
    }

    let mut guards: Vec<Guard<RSym>> = Vec::new();
    guards.extend(a.guards.iter().map(|g| g.map_syms(|s| ren.apply(kr, 0, s))));
    guards.extend(b.guards.iter().map(|g| g.map_syms(|s| ren.apply(kr, 1, s))));
    let les = le_forms(&guards);

    // Guard-driven separation (e.g. `tidA < s` vs. a read of `tid + s`).
    if disjoint(&d, &les, kr, &[], a.slop, b.slop, wa, wb) {
        return false;
    }

    // Case split when one instance's tid is pinned by an equality guard
    // (`if (tid == 0) ...`): the *other* thread is then confined to one
    // side of the pin. Only valid when the kernel reads a single tid
    // dimension, so "distinct threads" means exactly "this coordinate
    // differs".
    let used: Vec<usize> = (0..3).filter(|&d| kr.tid_dims[d]).collect();
    if let [dim] = used[..] {
        let dim = dim as u8;
        let pin_a = pinned(&guards, 0, dim);
        let pin_b = pinned(&guards, 1, dim);
        match (pin_a, pin_b) {
            (Some(pa), Some(pb)) => {
                if pa == pb {
                    return false; // both instances forced to one thread
                }
                let over = [
                    (RSym::Inst(0, Sym::Tid(dim)), Itv::point(pa)),
                    (RSym::Inst(1, Sym::Tid(dim)), Itv::point(pb)),
                ];
                return !disjoint(&d, &les, kr, &over, a.slop, b.slop, wa, wb);
            }
            (Some(p), None) | (None, Some(p)) => {
                let pinned_inst = if pin_a.is_some() { 0 } else { 1 };
                let free = RSym::Inst(1 - pinned_inst, Sym::Tid(dim));
                let mut all_clear = true;
                for side in [Itv::range(0, p - 1), Itv::range(p + 1, POS_INF)] {
                    if side.is_empty() {
                        continue;
                    }
                    let over = [
                        (RSym::Inst(pinned_inst, Sym::Tid(dim)), Itv::point(p)),
                        (free, side),
                    ];
                    if !disjoint(&d, &les, kr, &over, a.slop, b.slop, wa, wb) {
                        all_clear = false;
                        break;
                    }
                }
                return !all_clear;
            }
            (None, None) => {}
        }
    }

    true
}

/// The two byte ranges `[A+slopA.lo, A+slopA.hi+wa)` / `[B+slopB.lo,
/// B+slopB.hi+wb)` are provably disjoint under the guards.
#[allow(clippy::too_many_arguments)]
fn disjoint(
    d: &Affine<RSym>,
    les: &[Affine<RSym>],
    kr: &KernelReport,
    over: &[(RSym, Itv)],
    sa: Itv,
    sb: Itv,
    wa: i128,
    wb: i128,
) -> bool {
    let bounds = |rs: RSym| {
        if let Some(&(_, itv)) = over.iter().find(|(s, _)| *s == rs) {
            return itv;
        }
        load_sym_itv(kr, rs.base())
    };
    // b starts at or after a ends:
    if lower_bound(d, les, &bounds, DEPTH) >= sa.hi.saturating_add(wa).saturating_sub(sb.lo) {
        return true;
    }
    // a starts at or after b ends:
    upper_bound(d, les, &bounds, DEPTH) <= sa.lo.saturating_sub(sb.hi).saturating_sub(wb)
}

fn load_sym_itv(kr: &KernelReport, s: Sym) -> Itv {
    match s {
        Sym::Tid(_) | Sym::Ctaid(_) | Sym::CtaidNtid(_) => Itv::range(0, POS_INF),
        Sym::Ntid(_) | Sym::Nctaid(_) => Itv::range(1, POS_INF),
        Sym::Param(i) => kr.param_itv.get(i as usize).copied().unwrap_or(Itv::TOP),
        Sym::Opaque(q) => kr.opaques.get(q as usize).map(|o| o.itv).unwrap_or(Itv::TOP),
    }
}

/// Find a constant `p` with `tid(dim) = p` forced by instance `inst`'s
/// equality guards.
fn pinned(guards: &[Guard<RSym>], inst: u8, dim: u8) -> Option<i128> {
    for g in guards {
        if let Guard::Eq(e) = g {
            if e.terms.len() == 1 {
                let (&s, &c) = e.terms.iter().next().unwrap();
                if s == RSym::Inst(inst, Sym::Tid(dim)) && c != 0 && e.k % c == 0 {
                    return Some(-e.k / c);
                }
            }
        }
    }
    None
}

/// Exact strided-form disjointness from thread distinctness alone.
///
/// Succeeds when `d = k + Σ_dim c·(tidB − tidA)` over tid symbols only,
/// the paired dims cover every tid dimension the kernel reads (distinct
/// threads ⇒ some covered coordinate differs), and the minimum possible
/// `|d|` over a nonzero coordinate delta is at least the access width.
/// Multi-dim forms additionally require `k = 0` and rest on the usual
/// mixed-radix thread layout (DESIGN.md §12 records the assumption).
fn digit_disjoint(kr: &KernelReport, d: &Affine<RSym>, w: i128) -> bool {
    let mut per_dim: BTreeMap<u8, (i128, i128)> = BTreeMap::new();
    for (&s, &c) in &d.terms {
        match s {
            RSym::Inst(0, Sym::Tid(dim)) => per_dim.entry(dim).or_insert((0, 0)).0 = c,
            RSym::Inst(1, Sym::Tid(dim)) => per_dim.entry(dim).or_insert((0, 0)).1 = c,
            _ => return false,
        }
    }
    let mut coeffs: Vec<(u8, i128)> = Vec::new();
    for (dim, (c0, c1)) in per_dim {
        if c1 != -c0 || c1 == 0 {
            return false;
        }
        coeffs.push((dim, c1));
    }
    for dim in 0..3u8 {
        if kr.tid_dims[dim as usize] && !coeffs.iter().any(|&(d2, _)| d2 == dim) {
            return false;
        }
    }
    match coeffs[..] {
        [] => false,
        [(_, c)] => {
            // min |c·Δ + k| over nonzero integers Δ; |c·Δ + k| is V-shaped
            // in Δ, so the minimum sits at an integer adjacent to the
            // vertex -k/c (or at ±1 when the vertex rounds to zero).
            let k = d.k;
            let q = (-k).div_euclid(c);
            [q - 1, q, q + 1, -1, 1]
                .into_iter()
                .filter(|&dl| dl != 0)
                .map(|dl| (c.saturating_mul(dl).saturating_add(k)).abs())
                .min()
                .is_some_and(|m| m >= w)
        }
        _ => d.k == 0 && coeffs.iter().all(|&(_, c)| c.abs() >= w),
    }
}
