//! The affine value domain underneath the static analyzer (DESIGN.md §12).
//!
//! Every integer register is approximated as an **affine form** over a
//! small symbol alphabet — thread/block coordinates, launch dimensions,
//! kernel parameters, and per-loop opaque symbols minted at widening
//! points — plus a conservative interval of slop. Address disjointness
//! (the race detector) and access bounds (pre-flight OOB) are both
//! questions about the range of an affine expression under a set of
//! affine inequalities, answered here by interval evaluation sharpened
//! with a small Fourier–Motzkin-style guard substitution.
//!
//! Arithmetic is done in `i128` with saturating infinities so that launch
//! geometry as large as `u32` grids times `u64` params can never wrap the
//! analysis itself. Note the analysis models *mathematical* integers: a
//! `u32` subtraction that wraps at runtime is treated as its un-wrapped
//! value (guards like `i < n` make the wrapped case infeasible in the
//! kernels we accept; see DESIGN.md §12 for the soundness discussion).

use std::collections::BTreeMap;
use std::fmt;

/// Saturating "minus infinity". `i128::MIN / 4` keeps headroom so that
/// sums/products of two infinities still clamp instead of wrapping.
pub const NEG_INF: i128 = i128::MIN / 4;
/// Saturating "plus infinity".
pub const POS_INF: i128 = i128::MAX / 4;

fn clamp(v: i128) -> i128 {
    v.clamp(NEG_INF, POS_INF)
}

fn sat_add(a: i128, b: i128) -> i128 {
    clamp(a.saturating_add(b))
}

fn sat_mul(a: i128, b: i128) -> i128 {
    clamp(a.saturating_mul(b))
}

/// A closed interval `[lo, hi]` with saturating endpoints.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Itv {
    pub lo: i128,
    pub hi: i128,
}

impl Itv {
    pub const TOP: Itv = Itv { lo: NEG_INF, hi: POS_INF };
    pub const ZERO: Itv = Itv { lo: 0, hi: 0 };

    pub fn point(v: i128) -> Itv {
        let v = clamp(v);
        Itv { lo: v, hi: v }
    }

    pub fn range(lo: i128, hi: i128) -> Itv {
        Itv { lo: clamp(lo), hi: clamp(hi) }
    }

    pub fn is_point(&self) -> bool {
        self.lo == self.hi
    }

    pub fn is_empty(&self) -> bool {
        self.lo > self.hi
    }

    pub fn add(self, o: Itv) -> Itv {
        Itv { lo: sat_add(self.lo, o.lo), hi: sat_add(self.hi, o.hi) }
    }

    pub fn neg(self) -> Itv {
        Itv { lo: clamp(-self.hi), hi: clamp(-self.lo) }
    }

    pub fn sub(self, o: Itv) -> Itv {
        self.add(o.neg())
    }

    /// Multiply by a constant.
    pub fn scale(self, c: i128) -> Itv {
        let (a, b) = (sat_mul(self.lo, c), sat_mul(self.hi, c));
        Itv { lo: a.min(b), hi: a.max(b) }
    }

    pub fn mul(self, o: Itv) -> Itv {
        let ps = [
            sat_mul(self.lo, o.lo),
            sat_mul(self.lo, o.hi),
            sat_mul(self.hi, o.lo),
            sat_mul(self.hi, o.hi),
        ];
        Itv {
            lo: ps.iter().copied().min().unwrap(),
            hi: ps.iter().copied().max().unwrap(),
        }
    }

    pub fn join(self, o: Itv) -> Itv {
        Itv { lo: self.lo.min(o.lo), hi: self.hi.max(o.hi) }
    }
}

impl fmt::Display for Itv {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let end = |v: i128, f: &mut fmt::Formatter<'_>| -> fmt::Result {
            if v <= NEG_INF {
                write!(f, "-inf")
            } else if v >= POS_INF {
                write!(f, "+inf")
            } else {
                write!(f, "{v}")
            }
        };
        write!(f, "[")?;
        end(self.lo, f)?;
        write!(f, ", ")?;
        end(self.hi, f)?;
        write!(f, "]")
    }
}

/// Loop-head widening: endpoints that keep moving jump straight to zero
/// (the ubiquitous "counts down/up through non-negatives" case) and then
/// to infinity, so every loop stabilizes in at most three rounds.
pub fn widen(prev: Itv, next: Itv) -> Itv {
    let lo = if next.lo >= prev.lo {
        prev.lo
    } else if next.lo >= 0 {
        0
    } else {
        NEG_INF
    };
    let hi = if next.hi <= prev.hi { prev.hi } else { POS_INF };
    Itv { lo, hi }
}

/// The symbol alphabet of affine forms. Dimension components are stored
/// as their `Dim::index()` (0 = x, 1 = y, 2 = z) because `Dim` itself
/// does not order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Sym {
    /// `threadIdx.<dim>` — in `[0, ntid-1]`.
    Tid(u8),
    /// `blockDim.<dim>`.
    Ntid(u8),
    /// `blockIdx.<dim>` — in `[0, nctaid-1]`.
    Ctaid(u8),
    /// `gridDim.<dim>`.
    Nctaid(u8),
    /// The product `blockIdx.<dim> * blockDim.<dim>`, recognized as its
    /// own symbol so the universal `global_id = ctaid*ntid + tid` pattern
    /// stays affine (a product of two symbols is otherwise non-affine).
    CtaidNtid(u8),
    /// The value of scalar kernel parameter `i` (symbolic at module load,
    /// a concrete point at launch pre-flight).
    Param(u32),
    /// A loop-widened unknown: minted once per `(loop, register)` at the
    /// loop head, carrying only the widened interval recorded in the
    /// kernel's opaque table.
    Opaque(u32),
}

fn dim_name(d: u8) -> &'static str {
    ["x", "y", "z"][d as usize % 3]
}

impl fmt::Display for Sym {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Sym::Tid(d) => write!(f, "tid.{}", dim_name(*d)),
            Sym::Ntid(d) => write!(f, "ntid.{}", dim_name(*d)),
            Sym::Ctaid(d) => write!(f, "ctaid.{}", dim_name(*d)),
            Sym::Nctaid(d) => write!(f, "nctaid.{}", dim_name(*d)),
            Sym::CtaidNtid(d) => {
                write!(f, "ctaid.{d}*ntid.{d}", d = dim_name(*d))
            }
            Sym::Param(i) => write!(f, "param{i}"),
            Sym::Opaque(q) => write!(f, "loopvar{q}"),
        }
    }
}

/// An affine expression `k + Σ cᵢ·sᵢ` over symbols `S` (by default the
/// kernel alphabet [`Sym`]; the race detector instantiates it over
/// per-thread-instance renamings).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Affine<S: Ord + Copy = Sym> {
    pub k: i128,
    pub terms: BTreeMap<S, i128>,
}

impl<S: Ord + Copy> Affine<S> {
    pub fn konst(k: i128) -> Affine<S> {
        Affine { k: clamp(k), terms: BTreeMap::new() }
    }

    pub fn sym(s: S) -> Affine<S> {
        let mut terms = BTreeMap::new();
        terms.insert(s, 1);
        Affine { k: 0, terms }
    }

    pub fn as_const(&self) -> Option<i128> {
        self.terms.is_empty().then_some(self.k)
    }

    pub fn add(&self, o: &Affine<S>) -> Affine<S> {
        let mut r = self.clone();
        r.k = sat_add(r.k, o.k);
        for (&s, &c) in &o.terms {
            let e = r.terms.entry(s).or_insert(0);
            *e = sat_add(*e, c);
            if *e == 0 {
                r.terms.remove(&s);
            }
        }
        r
    }

    pub fn add_const(&self, c: i128) -> Affine<S> {
        let mut r = self.clone();
        r.k = sat_add(r.k, c);
        r
    }

    pub fn neg(&self) -> Affine<S> {
        self.scale(-1)
    }

    pub fn sub(&self, o: &Affine<S>) -> Affine<S> {
        self.add(&o.neg())
    }

    pub fn scale(&self, c: i128) -> Affine<S> {
        if c == 0 {
            return Affine::konst(0);
        }
        Affine {
            k: sat_mul(self.k, c),
            terms: self.terms.iter().map(|(&s, &t)| (s, sat_mul(t, c))).collect(),
        }
    }

    /// Substitute/rename every symbol through `f`, merging collisions.
    pub fn map_syms<T: Ord + Copy>(&self, f: impl Fn(S) -> T) -> Affine<T> {
        let mut r: Affine<T> = Affine::konst(self.k);
        for (&s, &c) in &self.terms {
            let e = r.terms.entry(f(s)).or_insert(0);
            *e = sat_add(*e, c);
        }
        r.terms.retain(|_, c| *c != 0);
        r
    }

    /// Interval of the expression under per-symbol bounds.
    pub fn eval(&self, bounds: &impl Fn(S) -> Itv) -> Itv {
        let mut r = Itv::point(self.k);
        for (&s, &c) in &self.terms {
            r = r.add(bounds(s).scale(c));
        }
        r
    }
}

impl fmt::Display for Affine<Sym> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut first = true;
        for (s, c) in &self.terms {
            if first {
                if *c == 1 {
                    write!(f, "{s}")?;
                } else {
                    write!(f, "{c}*{s}")?;
                }
                first = false;
            } else if *c < 0 {
                write!(f, " - {}*{s}", -c)?;
            } else if *c == 1 {
                write!(f, " + {s}")?;
            } else {
                write!(f, " + {c}*{s}")?;
            }
        }
        if first {
            write!(f, "{}", self.k)
        } else if self.k < 0 {
            write!(f, " - {}", -self.k)
        } else if self.k > 0 {
            write!(f, " + {}", self.k)
        } else {
            Ok(())
        }
    }
}

/// A path condition attached to an access: either `e ≤ 0` or `e = 0`
/// over the same affine alphabet as the offsets it guards.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Guard<S: Ord + Copy = Sym> {
    Le(Affine<S>),
    Eq(Affine<S>),
}

impl<S: Ord + Copy> Guard<S> {
    pub fn map_syms<T: Ord + Copy>(&self, f: impl Fn(S) -> T) -> Guard<T> {
        match self {
            Guard::Le(e) => Guard::Le(e.map_syms(&f)),
            Guard::Eq(e) => Guard::Eq(e.map_syms(&f)),
        }
    }
}

/// Flatten guards into their `e ≤ 0` forms (an equality contributes both
/// directions).
pub fn le_forms<S: Ord + Copy>(guards: &[Guard<S>]) -> Vec<Affine<S>> {
    let mut les = Vec::with_capacity(guards.len());
    for g in guards {
        match g {
            Guard::Le(e) => les.push(e.clone()),
            Guard::Eq(e) => {
                les.push(e.clone());
                les.push(e.neg());
            }
        }
    }
    les
}

/// Recursion budget for guard substitution. Each level eliminates one
/// symbol occurrence through one inequality; real kernel guards are one
/// or two deep.
const SUBST_DEPTH: u32 = 4;

/// Upper-bound `e` given inequalities `g ≤ 0`: besides plain interval
/// evaluation, any guard whose coefficient on a shared symbol divides
/// `e`'s with a positive quotient `c` yields `e ≤ e - c·g` (since
/// `-c·g ≥ 0`), recursively — a bounded Fourier–Motzkin elimination.
pub fn upper_bound<S: Ord + Copy>(
    e: &Affine<S>,
    les: &[Affine<S>],
    bounds: &impl Fn(S) -> Itv,
    depth: u32,
) -> i128 {
    let mut best = e.eval(bounds).hi;
    if depth == 0 || e.terms.is_empty() {
        return best;
    }
    for g in les {
        for (&s, &ec) in &e.terms {
            if let Some(&gc) = g.terms.get(&s) {
                if gc != 0 && ec % gc == 0 && ec / gc > 0 {
                    let e2 = e.sub(&g.scale(ec / gc));
                    best = best.min(upper_bound(&e2, les, bounds, depth - 1));
                }
            }
        }
    }
    best
}

pub fn lower_bound<S: Ord + Copy>(
    e: &Affine<S>,
    les: &[Affine<S>],
    bounds: &impl Fn(S) -> Itv,
    depth: u32,
) -> i128 {
    clamp(-upper_bound(&e.neg(), les, bounds, depth))
}

/// Guard-sharpened range of `e`.
pub fn bound<S: Ord + Copy>(
    e: &Affine<S>,
    les: &[Affine<S>],
    bounds: &impl Fn(S) -> Itv,
) -> Itv {
    Itv {
        lo: lower_bound(e, les, bounds, SUBST_DEPTH),
        hi: upper_bound(e, les, bounds, SUBST_DEPTH),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn b_top(_: Sym) -> Itv {
        Itv::TOP
    }

    #[test]
    fn affine_arith_normalizes() {
        let t = Affine::sym(Sym::Tid(0));
        let e = t.scale(4).add(&Affine::konst(8)).sub(&t.scale(4));
        assert_eq!(e.as_const(), Some(8));
        assert!(e.terms.is_empty());
    }

    #[test]
    fn widen_jumps_to_zero_then_inf() {
        let w1 = widen(Itv::point(128), Itv::range(64, 128));
        assert_eq!(w1, Itv::range(64, 128));
        let w2 = widen(w1, Itv::range(32, 128));
        assert_eq!(w2, Itv::range(0, 128));
        let w3 = widen(w2, Itv::range(-1, 256));
        assert_eq!(w3, Itv::TOP);
    }

    #[test]
    fn guard_substitution_bounds_guarded_index() {
        // i = tid + ctaid*ntid, guard i < n, param n concrete: the byte
        // offset 4*i is bounded by 4n - 4 even though tid alone is not.
        let i = Affine::sym(Sym::Tid(0)).add(&Affine::sym(Sym::CtaidNtid(0)));
        let n = Affine::sym(Sym::Param(1));
        // i < n  <=>  i - n + 1 <= 0
        let g = i.sub(&n).add_const(1);
        let off = i.scale(4);
        let bounds = |s: Sym| match s {
            Sym::Tid(_) | Sym::CtaidNtid(_) => Itv::range(0, POS_INF),
            Sym::Param(_) => Itv::point(1000),
            _ => Itv::TOP,
        };
        assert_eq!(upper_bound(&off, &[g], &bounds, SUBST_DEPTH), 4 * 1000 - 4);
        assert_eq!(lower_bound(&off, &[], &bounds, SUBST_DEPTH), 0);
    }

    #[test]
    fn unguarded_index_stays_unbounded() {
        let off = Affine::sym(Sym::Tid(0)).scale(4);
        assert!(upper_bound(&off, &[], &b_top, SUBST_DEPTH) >= POS_INF);
    }
}
