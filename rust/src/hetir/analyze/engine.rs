//! The affine abstract interpreter over hetIR's structured control flow.
//!
//! One forward pass per kernel computes, for every virtual register at
//! every program point, an [`Approx`]: an affine form over [`Sym`]s plus
//! interval slop. `While` loops run a bounded "quiet" fixpoint with
//! widening at the loop head (changing registers become per-loop
//! [`Sym::Opaque`] symbols), then one final *recording* pass collects:
//!
//! * every shared/global memory [`Access`] with its offset form, path
//!   conditions ([`Guard`]s), and barrier-interval label,
//! * barrier-interval structure: labels allocated at each `Bar`, merged
//!   through a union-find when a uniform `If` barriers on only some
//!   paths, plus loop backedge records (`tail → head`),
//! * uninitialized-read diagnostics (must-init meet at joins).

use super::affine::{widen, Affine, Guard, Itv, Sym, NEG_INF, POS_INF};
use super::{
    Access, AccessKind, Diagnostic, KernelReport, OpaqueInfo, Prov, SegKind, Severity, StmtPath,
};
use crate::hetir::instr::{Address, BinOp, CmpOp, Inst, Operand, Reg, SpecialReg, UnOp};
use crate::hetir::module::{Kernel, Stmt};
use crate::hetir::passes::uniformity::{self, Uniformity};
use crate::hetir::types::{AddrSpace, Scalar, Type, Value};
use std::collections::{HashMap, HashSet};

/// Iteration budget for the loop-head fixpoint. Widening jumps endpoints
/// to 0 and then ±inf, so real loops stabilize in 3–4 rounds; the cap is
/// a safety net, and overshooting it only loses precision (the final
/// head env is still an over-approximation joined through widening).
const FIXPOINT_ITERS: u32 = 8;

/// An abstract integer value: `form + slop`.
#[derive(Debug, Clone, PartialEq)]
pub(crate) struct Approx {
    pub form: Affine,
    pub slop: Itv,
}

impl Approx {
    pub fn exact(form: Affine) -> Approx {
        Approx { form, slop: Itv::ZERO }
    }

    pub fn konst(k: i128) -> Approx {
        Approx::exact(Affine::konst(k))
    }

    pub fn top() -> Approx {
        Approx { form: Affine::konst(0), slop: Itv::TOP }
    }

    pub fn from_itv(i: Itv) -> Approx {
        Approx { form: Affine::konst(0), slop: i }
    }

    pub fn is_exact(&self) -> bool {
        self.slop == Itv::ZERO
    }

    pub fn as_const(&self) -> Option<i128> {
        if self.is_exact() {
            self.form.as_const()
        } else {
            None
        }
    }

    pub fn add(&self, o: &Approx) -> Approx {
        Approx { form: self.form.add(&o.form), slop: self.slop.add(o.slop) }
    }

    pub fn sub(&self, o: &Approx) -> Approx {
        Approx { form: self.form.sub(&o.form), slop: self.slop.sub(o.slop) }
    }

    pub fn neg(&self) -> Approx {
        Approx { form: self.form.neg(), slop: self.slop.neg() }
    }

    pub fn scale(&self, c: i128) -> Approx {
        Approx { form: self.form.scale(c), slop: self.slop.scale(c) }
    }

    pub fn add_const(&self, c: i128) -> Approx {
        Approx { form: self.form.add_const(c), slop: self.slop }
    }
}

/// What a pointer register points at: a region plus a byte offset.
#[derive(Debug, Clone, PartialEq)]
struct PtrVal {
    prov: Prov,
    off: Approx,
}

/// A predicate register's symbolic condition, kept so branch guards can
/// be derived at the `If` that consumes it. `&&`/`||` arrive from the
/// frontend as predicated regions, reassembled at the join (see
/// `join_cond`).
#[derive(Debug, Clone, PartialEq)]
pub(crate) enum CondExpr {
    Cmp { op: CmpOp, lhs: Approx, rhs: Approx },
    And(Box<CondExpr>, Box<CondExpr>),
    Or(Box<CondExpr>, Box<CondExpr>),
    Not(Box<CondExpr>),
}

/// Per-register abstract state.
#[derive(Debug, Clone, PartialEq)]
struct AbsVal {
    ap: Approx,
    init: bool,
    ptr: Option<PtrVal>,
    cond: Option<CondExpr>,
}

impl AbsVal {
    fn top_uninit() -> AbsVal {
        AbsVal { ap: Approx::top(), init: false, ptr: None, cond: None }
    }
}

type Env = Vec<AbsVal>;

/// Result of abstractly executing a statement block.
struct Out {
    /// Environment at normal fall-through (`None` = all paths left the
    /// block through break/continue/return).
    fall: Option<Env>,
    brks: Vec<Env>,
    conts: Vec<Env>,
}

struct Ctx<'a> {
    k: &'a Kernel,
    uni: Uniformity,
    /// Off during loop fixpoints: no accesses, labels, or diagnostics.
    record: bool,
    opaque_ids: HashMap<(u32, u32), u32>,
    opaques: Vec<OpaqueInfo>,
    loop_ids: HashMap<Vec<(SegKind, u32)>, u32>,
    loop_parent: Vec<Option<u32>>,
    loop_stack: Vec<u32>,
    label: u32,
    next_label: u32,
    parent: Vec<u32>,
    accesses: Vec<Access>,
    backedges: Vec<(u32, u32, u32)>,
    diags: Vec<Diagnostic>,
    uninit_flagged: HashSet<u32>,
    guards: Vec<Guard>,
    /// Count of enclosing conditions that produced no guards — accesses
    /// under any such condition are not `provable` for pre-flight.
    unknown_conds: u32,
    path: Vec<(SegKind, u32)>,
    param_itv: Vec<Itv>,
    tid_dims: [bool; 3],
    uses_buf: Vec<Reg>,
}

/// Run the engine over one kernel.
pub(crate) fn run(k: &Kernel) -> KernelReport {
    let uni = uniformity::run(k);
    let param_itv: Vec<Itv> = k.params.iter().map(|p| type_itv(p.ty)).collect();
    let mut ctx = Ctx {
        k,
        uni,
        record: true,
        opaque_ids: HashMap::new(),
        opaques: Vec::new(),
        loop_ids: HashMap::new(),
        loop_parent: Vec::new(),
        loop_stack: Vec::new(),
        label: 0,
        next_label: 1,
        parent: vec![0],
        accesses: Vec::new(),
        backedges: Vec::new(),
        diags: Vec::new(),
        uninit_flagged: HashSet::new(),
        guards: Vec::new(),
        unknown_conds: 0,
        path: Vec::new(),
        param_itv,
        tid_dims: [false; 3],
        uses_buf: Vec::new(),
    };
    let env = ctx.initial_env();
    let _ = ctx.run_block(&k.body, env, SegKind::Body);

    // Canonicalize barrier-interval labels through the union-find.
    let mut accesses = std::mem::take(&mut ctx.accesses);
    for a in &mut accesses {
        a.label = ctx.find(a.label);
    }
    let mut backedges: Vec<(u32, u32, u32)> =
        ctx.backedges.clone().into_iter().map(|(t, e, l)| (ctx.find(t), ctx.find(e), l)).collect();
    backedges.sort_unstable();
    backedges.dedup();

    KernelReport {
        name: k.name.clone(),
        diags: ctx.diags,
        accesses,
        opaques: ctx.opaques,
        loop_parent: ctx.loop_parent,
        backedges,
        tid_dims: ctx.tid_dims,
        param_itv: ctx.param_itv,
        analysis_nanos: 0,
    }
}

fn type_itv(ty: Type) -> Itv {
    match ty {
        Type::Scalar(Scalar::Pred) => Itv::range(0, 1),
        Type::Scalar(Scalar::I32) => Itv::range(i32::MIN as i128, i32::MAX as i128),
        Type::Scalar(Scalar::U32) => Itv::range(0, u32::MAX as i128),
        Type::Scalar(Scalar::I64) => Itv::range(i64::MIN as i128, i64::MAX as i128),
        Type::Scalar(Scalar::U64) => Itv::range(0, u64::MAX as i128),
        _ => Itv::TOP,
    }
}

fn imm_math(v: &Value) -> Option<i128> {
    match v.ty {
        Type::Scalar(Scalar::Pred) => Some((v.bits & 1) as i128),
        Type::Scalar(Scalar::I32) => Some((v.bits as u32 as i32) as i128),
        Type::Scalar(Scalar::U32) => Some((v.bits as u32) as i128),
        Type::Scalar(Scalar::I64) => Some((v.bits as i64) as i128),
        Type::Scalar(Scalar::U64) => Some(v.bits as i128),
        _ => None,
    }
}

impl<'a> Ctx<'a> {
    fn initial_env(&self) -> Env {
        let mut env = vec![AbsVal::top_uninit(); self.k.reg_types.len()];
        for (i, p) in self.k.params.iter().enumerate() {
            let v = &mut env[i];
            v.init = true;
            match p.ty {
                Type::Ptr(AddrSpace::Global) => {
                    v.ptr =
                        Some(PtrVal { prov: Prov::Param(i as u32), off: Approx::konst(0) });
                }
                Type::Ptr(AddrSpace::Shared) => {
                    v.ptr = Some(PtrVal { prov: Prov::Shared, off: Approx::top() });
                }
                Type::Scalar(s) if s.is_int() => {
                    v.ap = Approx::exact(Affine::sym(Sym::Param(i as u32)));
                }
                _ => {}
            }
        }
        env
    }

    fn sym_itv(&self, s: Sym) -> Itv {
        match s {
            Sym::Tid(_) | Sym::Ctaid(_) | Sym::CtaidNtid(_) => Itv::range(0, POS_INF),
            Sym::Ntid(_) | Sym::Nctaid(_) => Itv::range(1, POS_INF),
            Sym::Param(i) => self.param_itv.get(i as usize).copied().unwrap_or(Itv::TOP),
            Sym::Opaque(q) => {
                self.opaques.get(q as usize).map(|o| o.itv).unwrap_or(Itv::TOP)
            }
        }
    }

    fn ap_itv(&self, a: &Approx) -> Itv {
        a.form.eval(&|s| self.sym_itv(s)).add(a.slop)
    }

    // ---- joins -----------------------------------------------------

    fn join_ap(&self, a: &Approx, b: &Approx) -> Approx {
        if a == b {
            a.clone()
        } else if a.form == b.form {
            Approx { form: a.form.clone(), slop: a.slop.join(b.slop) }
        } else {
            Approx::from_itv(self.ap_itv(a).join(self.ap_itv(b)))
        }
    }

    fn join_val(&self, a: &AbsVal, b: &AbsVal, cond: Option<&CondExpr>) -> AbsVal {
        let ptr = match (&a.ptr, &b.ptr) {
            (Some(x), Some(y)) if x.prov == y.prov => {
                Some(PtrVal { prov: x.prov, off: self.join_ap(&x.off, &y.off) })
            }
            _ => None,
        };
        AbsVal {
            ap: self.join_ap(&a.ap, &b.ap),
            init: a.init && b.init,
            ptr,
            cond: join_cond(&a.cond, &b.cond, cond),
        }
    }

    fn join_env(&self, a: &Env, b: &Env, cond: Option<&CondExpr>) -> Env {
        a.iter().zip(b).map(|(x, y)| self.join_val(x, y, cond)).collect()
    }

    // ---- barrier-interval labels -----------------------------------

    fn fresh_label(&mut self) -> u32 {
        let l = self.next_label;
        self.next_label += 1;
        self.parent.push(l);
        l
    }

    fn find(&mut self, mut x: u32) -> u32 {
        while self.parent[x as usize] != x {
            let p = self.parent[x as usize];
            self.parent[x as usize] = self.parent[p as usize];
            x = self.parent[x as usize];
        }
        x
    }

    fn union(&mut self, a: u32, b: u32) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra != rb {
            self.parent[ra as usize] = rb;
        }
    }

    // ---- operand evaluation ----------------------------------------

    fn op_ap(&self, o: &Operand, env: &Env) -> Approx {
        match o {
            Operand::Reg(r) => env[r.0 as usize].ap.clone(),
            Operand::Imm(v) => imm_math(v).map(Approx::konst).unwrap_or_else(Approx::top),
        }
    }

    fn operand_val(&self, o: &Operand, env: &Env) -> AbsVal {
        match o {
            Operand::Reg(r) => {
                let mut v = env[r.0 as usize].clone();
                v.init = true;
                v
            }
            Operand::Imm(v) => {
                let mut a = AbsVal::top_uninit();
                a.init = true;
                match v.ty {
                    Type::Ptr(space) => {
                        a.ptr = Some(PtrVal {
                            prov: if space == AddrSpace::Shared {
                                Prov::Shared
                            } else {
                                Prov::Unknown
                            },
                            off: Approx::konst(v.bits as i128),
                        });
                    }
                    _ => {
                        if let Some(k) = imm_math(v) {
                            a.ap = Approx::konst(k);
                        }
                    }
                }
                a
            }
        }
    }

    fn addr_val(&self, a: &Address, env: &Env) -> (Prov, Approx) {
        let base = &env[a.base.0 as usize];
        let (prov, mut off) = match &base.ptr {
            Some(p) => (p.prov, p.off.clone()),
            None => (Prov::Unknown, Approx::top()),
        };
        if let Some(ix) = a.index {
            off = off.add(&env[ix.0 as usize].ap.scale(a.scale as i128));
        }
        (prov, off.add_const(a.disp as i128))
    }

    // ---- transfer function -----------------------------------------

    fn set(&self, env: &mut Env, dst: Reg, ap: Approx) {
        env[dst.0 as usize] = AbsVal { ap, init: true, ptr: None, cond: None };
    }

    fn record_access(
        &mut self,
        kind: AccessKind,
        space: AddrSpace,
        addr: &Address,
        width: u64,
        ordered: bool,
        env: &Env,
    ) {
        if !self.record {
            return;
        }
        let (prov, off) = self.addr_val(addr, env);
        self.accesses.push(Access {
            kind,
            space,
            prov,
            off: off.form,
            slop: off.slop,
            width,
            guards: self.guards.clone(),
            label: self.label,
            loops: self.loop_stack.clone(),
            path: StmtPath(self.path.clone()),
            provable: self.unknown_conds == 0,
            ordered_atomic: ordered,
        });
    }

    fn eval_inst(&mut self, i: &Inst, env: &mut Env) {
        // Must-init check: every register read must be initialized on all
        // paths reaching here. A flagged register is treated as
        // initialized afterwards so one bad def site produces one
        // diagnostic, not a cascade.
        let mut uses = std::mem::take(&mut self.uses_buf);
        uses.clear();
        i.uses(&mut uses);
        for r in &uses {
            let v = &mut env[r.0 as usize];
            if !v.init {
                v.init = true;
                if self.record && self.uninit_flagged.insert(r.0) {
                    self.diags.push(Diagnostic {
                        severity: Severity::Warning,
                        kernel: self.k.name.clone(),
                        path: StmtPath(self.path.clone()),
                        analysis: "uninit",
                        message: format!(
                            "register %{} may be read before initialization \
                             (not assigned on every path reaching this statement)",
                            r.0
                        ),
                    });
                }
            }
        }
        self.uses_buf = uses;

        match i {
            Inst::Special { dst, kind } => {
                let ap = match kind {
                    SpecialReg::ThreadIdx(d) => {
                        self.tid_dims[d.index()] = true;
                        Approx::exact(Affine::sym(Sym::Tid(d.index() as u8)))
                    }
                    SpecialReg::BlockIdx(d) => {
                        Approx::exact(Affine::sym(Sym::Ctaid(d.index() as u8)))
                    }
                    SpecialReg::BlockDim(d) => {
                        Approx::exact(Affine::sym(Sym::Ntid(d.index() as u8)))
                    }
                    SpecialReg::GridDim(d) => {
                        Approx::exact(Affine::sym(Sym::Nctaid(d.index() as u8)))
                    }
                    SpecialReg::GlobalId(d) => {
                        self.tid_dims[d.index()] = true;
                        Approx::exact(
                            Affine::sym(Sym::CtaidNtid(d.index() as u8))
                                .add(&Affine::sym(Sym::Tid(d.index() as u8))),
                        )
                    }
                };
                self.set(env, *dst, ap);
            }
            Inst::Mov { dst, src } => {
                env[dst.0 as usize] = self.operand_val(src, env);
            }
            Inst::Bin { op, ty, dst, a, b } => {
                let ap = if ty.is_float() {
                    Approx::top()
                } else {
                    let av = self.op_ap(a, env);
                    let bv = self.op_ap(b, env);
                    self.bin_ap(*op, &av, &bv)
                };
                self.set(env, *dst, ap);
            }
            Inst::Un { op, ty, dst, a } => {
                let src_cond = if let Operand::Reg(r) = a {
                    env[r.0 as usize].cond.clone()
                } else {
                    None
                };
                let ap = if ty.is_float() {
                    Approx::top()
                } else if *ty == Scalar::Pred {
                    Approx::from_itv(Itv::range(0, 1))
                } else {
                    let av = self.op_ap(a, env);
                    match op {
                        UnOp::Neg => av.neg(),
                        // bitwise not: !x = -x - 1
                        UnOp::Not => av.neg().add_const(-1),
                        UnOp::Abs => {
                            let i = self.ap_itv(&av);
                            let lo = if i.lo >= 0 { i.lo } else { 0 };
                            Approx::from_itv(Itv::range(lo, i.hi.abs().max(i.lo.abs())))
                        }
                        UnOp::Popc => Approx::from_itv(Itv::range(0, 64)),
                        _ => Approx::top(),
                    }
                };
                self.set(env, *dst, ap);
                if *op == UnOp::Not && *ty == Scalar::Pred {
                    env[dst.0 as usize].cond = src_cond.map(|c| CondExpr::Not(Box::new(c)));
                }
            }
            Inst::Fma { dst, .. } => self.set(env, *dst, Approx::top()),
            Inst::Cmp { op, ty, dst, a, b } => {
                let cond = if ty.is_float() {
                    None
                } else {
                    Some(CondExpr::Cmp {
                        op: *op,
                        lhs: self.op_ap(a, env),
                        rhs: self.op_ap(b, env),
                    })
                };
                env[dst.0 as usize] = AbsVal {
                    ap: Approx::from_itv(Itv::range(0, 1)),
                    init: true,
                    ptr: None,
                    cond,
                };
            }
            Inst::Sel { dst, a, b, .. } => {
                let av = self.operand_val(a, env);
                let bv = self.operand_val(b, env);
                let mut v = self.join_val(&av, &bv, None);
                v.init = true;
                env[dst.0 as usize] = v;
            }
            Inst::Cvt { from, to, dst, src } => {
                let ap = if from.is_int() && to.is_int() {
                    // Width/sign conversions keep the math value; wraps
                    // are outside the analysis' integer model (§12).
                    self.op_ap(src, env)
                } else {
                    Approx::top()
                };
                self.set(env, *dst, ap);
            }
            Inst::PtrAdd { dst, addr } => {
                let (prov, off) = self.addr_val(addr, env);
                env[dst.0 as usize] = AbsVal {
                    ap: Approx::top(),
                    init: true,
                    ptr: Some(PtrVal { prov, off }),
                    cond: None,
                };
            }
            Inst::Ld { space, ty, dst, addr } => {
                self.record_access(
                    AccessKind::Read,
                    *space,
                    addr,
                    ty.size_bytes() as u64,
                    false,
                    env,
                );
                self.set(env, *dst, Approx::top());
            }
            Inst::St { space, ty, addr, .. } => {
                self.record_access(
                    AccessKind::Write,
                    *space,
                    addr,
                    ty.size_bytes() as u64,
                    false,
                    env,
                );
            }
            Inst::Atom { op, space, ty, dst, addr, .. } => {
                self.record_access(
                    AccessKind::Atomic,
                    *space,
                    addr,
                    ty.size_bytes() as u64,
                    !op.commutes(),
                    env,
                );
                if let Some(d) = dst {
                    self.set(env, *d, Approx::top());
                }
            }
            Inst::Bar { .. } => {
                if self.record {
                    self.label = self.fresh_label();
                }
            }
            Inst::Fence { .. } | Inst::Trap { .. } => {}
            Inst::Vote { dst, .. } => {
                self.set(env, *dst, Approx::from_itv(Itv::range(0, 1)));
            }
            Inst::Ballot { dst, .. } => {
                self.set(env, *dst, Approx::from_itv(Itv::range(0, u32::MAX as i128)));
            }
            Inst::Shfl { dst, .. } => self.set(env, *dst, Approx::top()),
            Inst::Rng { dst, state } => {
                self.set(env, *dst, Approx::from_itv(Itv::range(0, u32::MAX as i128)));
                self.set(env, *state, Approx::from_itv(Itv::range(0, u32::MAX as i128)));
            }
        }
    }

    fn bin_ap(&self, op: BinOp, a: &Approx, b: &Approx) -> Approx {
        let ai = self.ap_itv(a);
        let bi = self.ap_itv(b);
        match op {
            BinOp::Add => a.add(b),
            BinOp::Sub => a.sub(b),
            BinOp::Mul => {
                if let Some(c) = a.as_const() {
                    return b.scale(c);
                }
                if let Some(c) = b.as_const() {
                    return a.scale(c);
                }
                if let Some(p) = prod_sym(a, b) {
                    return p;
                }
                Approx::from_itv(ai.mul(bi))
            }
            BinOp::Div => {
                if let Some(c) = b.as_const() {
                    if c > 0 {
                        if a.is_exact()
                            && a.form.k % c == 0
                            && a.form.terms.values().all(|t| t % c == 0)
                        {
                            // form = c * g exactly: truncating division is
                            // exact regardless of sign.
                            let mut f = a.form.clone();
                            f.k /= c;
                            for t in f.terms.values_mut() {
                                *t /= c;
                            }
                            return Approx::exact(f);
                        }
                        if ai.lo >= 0 {
                            return Approx::from_itv(Itv::range(ai.lo / c, ai.hi / c));
                        }
                    }
                }
                Approx::top()
            }
            BinOp::Rem => {
                if let Some(c) = b.as_const() {
                    if c > 0 {
                        if ai.lo >= 0 {
                            return Approx::from_itv(Itv::range(0, (c - 1).min(ai.hi)));
                        }
                        return Approx::from_itv(Itv::range(-(c - 1), c - 1));
                    }
                }
                Approx::top()
            }
            BinOp::Min => Approx::from_itv(Itv::range(ai.lo.min(bi.lo), ai.hi.min(bi.hi))),
            BinOp::Max => Approx::from_itv(Itv::range(ai.lo.max(bi.lo), ai.hi.max(bi.hi))),
            BinOp::And => {
                // x & m is in [0, m] for any x when m >= 0 (two's
                // complement: a non-negative mask caps the bits).
                if let Some(m) = b.as_const() {
                    if m >= 0 {
                        return Approx::from_itv(Itv::range(0, m));
                    }
                }
                if let Some(m) = a.as_const() {
                    if m >= 0 {
                        return Approx::from_itv(Itv::range(0, m));
                    }
                }
                if ai.lo >= 0 && bi.lo >= 0 {
                    Approx::from_itv(Itv::range(0, ai.hi.min(bi.hi)))
                } else {
                    Approx::top()
                }
            }
            BinOp::Or | BinOp::Xor => {
                if ai.lo >= 0 && bi.lo >= 0 {
                    // x|m <= x+m and x^m <= x+m for non-negative operands.
                    Approx::from_itv(Itv::range(0, Itv::range(ai.hi, ai.hi).add(bi).hi))
                } else {
                    Approx::top()
                }
            }
            BinOp::Shl => {
                if let Some(c) = b.as_const() {
                    if (0..=63).contains(&c) {
                        return a.scale(1i128 << c);
                    }
                }
                Approx::top()
            }
            BinOp::Shr => {
                if let Some(c) = b.as_const() {
                    if (0..=63).contains(&c) && ai.lo >= 0 {
                        return Approx::from_itv(Itv::range(ai.lo >> c, ai.hi >> c));
                    }
                }
                if ai.lo >= 0 {
                    Approx::from_itv(Itv::range(0, ai.hi))
                } else {
                    Approx::top()
                }
            }
        }
    }

    // ---- control flow ----------------------------------------------

    fn run_block(&mut self, stmts: &[Stmt], env: Env, seg: SegKind) -> Out {
        let mut env = Some(env);
        let mut out = Out { fall: None, brks: Vec::new(), conts: Vec::new() };
        let guards_base = self.guards.len();
        let unknown_base = self.unknown_conds;
        for (idx, s) in stmts.iter().enumerate() {
            let Some(mut cur) = env.take() else { break };
            self.path.push((seg, idx as u32));
            match s {
                Stmt::I(i) => {
                    self.eval_inst(i, &mut cur);
                    env = Some(cur);
                }
                Stmt::If { cond, then_b, else_b } => {
                    let cexpr = cur[cond.0 as usize].cond.clone();
                    let l0 = self.label;
                    let t_out = self.branch(then_b, cur.clone(), SegKind::Then, cexpr.as_ref(), true);
                    let lt = self.label;
                    self.label = l0;
                    let e_out = self.branch(else_b, cur, SegKind::Else, cexpr.as_ref(), false);
                    let le = self.label;
                    if self.record {
                        if lt != l0 || le != l0 {
                            // A (uniform) branch barriered: both arms drain
                            // into one joined interval, even when only one
                            // arm contained the barrier.
                            let lj = self.fresh_label();
                            self.union(lt, lj);
                            self.union(le, lj);
                            self.label = lj;
                        } else {
                            self.label = l0;
                        }
                    }
                    out.brks.extend(t_out.brks);
                    out.brks.extend(e_out.brks);
                    out.conts.extend(t_out.conts);
                    out.conts.extend(e_out.conts);
                    env = match (t_out.fall, e_out.fall) {
                        (Some(a), Some(b)) => Some(self.join_env(&a, &b, cexpr.as_ref())),
                        (Some(a), None) => {
                            // Early-exit else arm: everything after this
                            // statement in the block runs under the
                            // then-condition.
                            self.persist_guards(cexpr.as_ref(), true);
                            Some(a)
                        }
                        (None, Some(b)) => {
                            self.persist_guards(cexpr.as_ref(), false);
                            Some(b)
                        }
                        (None, None) => None,
                    };
                }
                Stmt::While { cond, cond_reg, body } => {
                    let o = self.do_while(cond, *cond_reg, body, cur);
                    env = o.fall;
                }
                Stmt::Break => {
                    out.brks.push(cur);
                }
                Stmt::Continue => {
                    out.conts.push(cur);
                }
                Stmt::Return => {}
            }
            self.path.pop();
        }
        self.guards.truncate(guards_base);
        self.unknown_conds = unknown_base;
        out.fall = env;
        out
    }

    /// Run a conditional arm with its branch guards pushed.
    fn branch(
        &mut self,
        stmts: &[Stmt],
        env: Env,
        seg: SegKind,
        cond: Option<&CondExpr>,
        sense: bool,
    ) -> Out {
        let gbase = self.guards.len();
        let ubase = self.unknown_conds;
        self.persist_guards(cond, sense);
        let out = self.run_block(stmts, env, seg);
        self.guards.truncate(gbase);
        self.unknown_conds = ubase;
        out
    }

    /// Push the guards of one condition side; an untranslatable condition
    /// counts as unknown (accesses under it lose `provable`).
    fn persist_guards(&mut self, cond: Option<&CondExpr>, sense: bool) {
        let gs = match cond {
            Some(c) if sense => guards_true(c),
            Some(c) => guards_false(c),
            None => Vec::new(),
        };
        if gs.is_empty() {
            self.unknown_conds += 1;
        } else {
            self.guards.extend(gs);
        }
    }

    fn do_while(&mut self, cond: &[Stmt], cond_reg: Reg, body: &[Stmt], env: Env) -> Out {
        let loop_id = self.loop_id_for_path();
        self.loop_stack.push(loop_id);

        // Quiet fixpoint: stabilize the loop-head env under widening.
        let saved_record = self.record;
        self.record = false;
        let mut head = env.clone();
        for _ in 0..FIXPOINT_ITERS {
            let (_, _, b_out) = self.loop_pass(cond, cond_reg, body, &head);
            let mut be: Option<Env> = b_out.fall;
            for c in b_out.conts {
                be = Some(match be {
                    Some(x) => self.join_env(&x, &c, None),
                    None => c,
                });
            }
            let joined = match &be {
                Some(b) => self.join_env(&env, b, None),
                None => env.clone(),
            };
            let (new_head, changed) = self.widen_head(&head, &joined, loop_id);
            head = new_head;
            if !changed {
                break;
            }
        }
        self.record = saved_record;

        // One recording pass from the stable head.
        let head_label = self.label;
        let (e1, _cexpr, b_out) = self.loop_pass(cond, cond_reg, body, &head);
        if self.record {
            let tail_label = self.label;
            self.backedges.push((tail_label, head_label, loop_id));
        }

        // Exit env: condition-false fall-through joined with breaks. The
        // post-loop label stays at the tail — a zero-trip loop would fall
        // through with the head label, a miss the advisory race detector
        // accepts (see DESIGN.md §12).
        let mut exit = e1;
        for b in b_out.brks {
            exit = self.join_env(&exit, &b, None);
        }
        self.loop_stack.pop();
        Out { fall: Some(exit), brks: Vec::new(), conts: Vec::new() }
    }

    fn loop_pass(
        &mut self,
        cond: &[Stmt],
        cond_reg: Reg,
        body: &[Stmt],
        head: &Env,
    ) -> (Env, Option<CondExpr>, Out) {
        let c_out = self.run_block(cond, head.clone(), SegKind::Cond);
        let e1 = c_out.fall.unwrap_or_else(|| head.clone());
        let cexpr = e1[cond_reg.0 as usize].cond.clone();
        let b_out = self.branch(body, e1.clone(), SegKind::Body, cexpr.as_ref(), true);
        (e1, cexpr, b_out)
    }

    fn loop_id_for_path(&mut self) -> u32 {
        if let Some(&id) = self.loop_ids.get(&self.path) {
            return id;
        }
        let id = self.loop_parent.len() as u32;
        self.loop_ids.insert(self.path.clone(), id);
        self.loop_parent.push(self.loop_stack.last().copied());
        id
    }

    fn opaque_for(&mut self, loop_id: u32, reg: u32) -> u32 {
        if let Some(&q) = self.opaque_ids.get(&(loop_id, reg)) {
            return q;
        }
        let q = self.opaques.len() as u32;
        self.opaque_ids.insert((loop_id, reg), q);
        self.opaques.push(OpaqueInfo {
            // Empty until the first widen records the first joined range.
            itv: Itv { lo: POS_INF, hi: NEG_INF },
            loop_id,
            uniform: self.uni.is_uniform(Reg(reg)),
        });
        q
    }

    /// Widen `joined` (entry ⊔ backedge) against the previous head env.
    /// Registers whose affine form is unstable become per-loop opaque
    /// symbols whose interval widens monotonically, so the fixpoint
    /// terminates in a handful of rounds.
    fn widen_head(&mut self, old: &Env, joined: &Env, loop_id: u32) -> (Env, bool) {
        let mut changed = false;
        let mut out = Vec::with_capacity(old.len());
        for (r, (o, j)) in old.iter().zip(joined).enumerate() {
            if o == j {
                out.push(o.clone());
                continue;
            }
            let mut v = j.clone();
            if o.ap != j.ap {
                let q = self.opaque_for(loop_id, r as u32);
                let jit = self.ap_itv(&j.ap);
                let prev = self.opaques[q as usize].itv;
                let w = if prev.is_empty() { jit } else { widen(prev, jit) };
                if w != prev {
                    self.opaques[q as usize].itv = w;
                    changed = true;
                }
                v.ap = Approx::exact(Affine::sym(Sym::Opaque(q)));
            }
            if o.ptr != j.ptr {
                v.ptr = None;
            } else {
                v.ptr = o.ptr.clone();
            }
            if o.cond != j.cond {
                v.cond = None;
            }
            v.init = o.init && j.init;
            if v != *o {
                changed = true;
            }
            out.push(v);
        }
        (out, changed)
    }
}

/// Recognize `ctaid.d * ntid.d` (either order) as its product symbol.
fn prod_sym(a: &Approx, b: &Approx) -> Option<Approx> {
    let single = |x: &Approx| -> Option<Sym> {
        if x.is_exact() && x.form.k == 0 && x.form.terms.len() == 1 {
            let (&s, &c) = x.form.terms.iter().next().unwrap();
            (c == 1).then_some(s)
        } else {
            None
        }
    };
    match (single(a)?, single(b)?) {
        (Sym::Ctaid(d1), Sym::Ntid(d2)) | (Sym::Ntid(d1), Sym::Ctaid(d2)) if d1 == d2 => {
            Some(Approx::exact(Affine::sym(Sym::CtaidNtid(d1))))
        }
        _ => None,
    }
}

/// Condition join at an `If` merge: reassembles the frontend's
/// short-circuit lowering. `a` is the then-arm value, `b` the else-arm
/// value, `c` the branch condition:
/// `a && b` lowers to `r = a; if (r) r = b` — at the join the else value
/// *is* the condition, so the merged value is `And(c, then)`. `a || b`
/// lowers through `if (!r) r = b`, recognized as `Or(else, then)`.
fn join_cond(
    a: &Option<CondExpr>,
    b: &Option<CondExpr>,
    c: Option<&CondExpr>,
) -> Option<CondExpr> {
    if a == b {
        return a.clone();
    }
    let (Some(av), Some(bv)) = (a, b) else { return None };
    let Some(c) = c else { return None };
    if bv == c {
        return Some(CondExpr::And(Box::new(c.clone()), Box::new(av.clone())));
    }
    if let CondExpr::Not(inner) = c {
        if **inner == *bv {
            return Some(CondExpr::Or(Box::new(bv.clone()), Box::new(av.clone())));
        }
    }
    None
}

fn negate_cmp(op: CmpOp) -> CmpOp {
    match op {
        CmpOp::Eq => CmpOp::Ne,
        CmpOp::Ne => CmpOp::Eq,
        CmpOp::Lt => CmpOp::Ge,
        CmpOp::Le => CmpOp::Gt,
        CmpOp::Gt => CmpOp::Le,
        CmpOp::Ge => CmpOp::Lt,
    }
}

/// Guards implied by `lhs <op> rhs` being true, as `e ≤ 0` / `e = 0`
/// forms over `d = lhs - rhs` (slop folded conservatively; infinite slop
/// yields nothing).
fn cmp_guards(op: CmpOp, lhs: &Approx, rhs: &Approx) -> Vec<Guard> {
    let d = lhs.sub(rhs);
    let (f, s) = (d.form, d.slop);
    match op {
        CmpOp::Lt if s.lo > NEG_INF => vec![Guard::Le(f.add_const(1 + s.lo))],
        CmpOp::Le if s.lo > NEG_INF => vec![Guard::Le(f.add_const(s.lo))],
        CmpOp::Gt if s.hi < POS_INF => vec![Guard::Le(f.neg().add_const(1 - s.hi))],
        CmpOp::Ge if s.hi < POS_INF => vec![Guard::Le(f.neg().add_const(-s.hi))],
        CmpOp::Eq => {
            if s == Itv::ZERO {
                vec![Guard::Eq(f)]
            } else {
                let mut g = Vec::new();
                if s.lo > NEG_INF {
                    g.push(Guard::Le(f.add_const(s.lo)));
                }
                if s.hi < POS_INF {
                    g.push(Guard::Le(f.neg().add_const(-s.hi)));
                }
                g
            }
        }
        _ => Vec::new(),
    }
}

pub(crate) fn guards_true(c: &CondExpr) -> Vec<Guard> {
    match c {
        CondExpr::Cmp { op, lhs, rhs } => cmp_guards(*op, lhs, rhs),
        CondExpr::And(a, b) => {
            let mut g = guards_true(a);
            g.extend(guards_true(b));
            g
        }
        CondExpr::Or(_, _) => Vec::new(),
        CondExpr::Not(x) => guards_false(x),
    }
}

pub(crate) fn guards_false(c: &CondExpr) -> Vec<Guard> {
    match c {
        CondExpr::Cmp { op, lhs, rhs } => cmp_guards(negate_cmp(*op), lhs, rhs),
        CondExpr::And(_, _) => Vec::new(),
        CondExpr::Or(a, b) => {
            let mut g = guards_false(a);
            g.extend(guards_false(b));
            g
        }
        CondExpr::Not(x) => guards_true(x),
    }
}
