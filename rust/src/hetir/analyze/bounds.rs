//! Bounds checking: symbolic at module load, concrete at launch
//! pre-flight (DESIGN.md §12).
//!
//! At load time only constant shared-memory offsets can be judged (the
//! shared window size is part of the kernel). Everything else stays
//! symbolic in launch dims and scalar params; `preflight_launch`
//! instantiates the recorded access forms against one concrete launch and
//! turns a provable out-of-bounds access into a typed
//! [`HetError::StaticFault`] **before any block executes**. An access
//! that merely *may* be out of bounds is left to the device-level fault
//! path — pre-flight only rejects what it can prove, so it never blocks a
//! correct launch.

use super::affine::{le_forms, lower_bound, upper_bound, Itv, Sym};
use super::{Diagnostic, KernelReport, Prov, Severity};
use crate::error::{HetError, Result};
use crate::hetir::module::Kernel;
use crate::hetir::types::AddrSpace;

/// Guard-substitution depth for bounds queries (see `affine::upper_bound`).
const DEPTH: u32 = 4;

/// Load-time pass: flag constant shared-memory offsets that fall outside
/// the kernel's static shared window. These are wrong at *every* launch,
/// so they are `Error`-severity diagnostics (a `Strict` launch gate).
pub(crate) fn load_time_check(kr: &mut KernelReport, k: &Kernel) {
    let mut diags = Vec::new();
    {
        let lb = kr.load_bounds();
        for a in &kr.accesses {
            if a.space != AddrSpace::Shared || a.prov != Prov::Shared {
                continue;
            }
            if !a.provable || !a.off.terms.is_empty() || !a.slop.is_point() {
                continue;
            }
            // A guard that can never hold means the access is dead code.
            let les = le_forms(&a.guards);
            if les.iter().any(|e| e.eval(&lb).lo > 0) {
                continue;
            }
            let off = a.off.k + a.slop.lo;
            let end = off + a.width as i128;
            if off < 0 || end > k.shared_bytes as i128 {
                diags.push(Diagnostic {
                    severity: Severity::Error,
                    kernel: kr.name.clone(),
                    path: a.path.clone(),
                    analysis: "bounds",
                    message: format!(
                        "shared-memory {} of {} byte(s) at constant offset {} is \
                         outside the kernel's {}-byte shared window",
                        a.kind.verb(),
                        a.width,
                        off,
                        k.shared_bytes
                    ),
                });
            }
        }
    }
    kr.diags.extend(diags);
}

/// Instantiate the kernel's recorded access forms against one concrete
/// launch and reject it if any access is **provably** out of bounds.
///
/// * `param_vals[i]` — the concrete value of scalar parameter `i`
///   (`None` for pointers or unresolvable args).
/// * `param_avail[i]` — for pointer parameter `i`, the byte size of the
///   allocation it points at (`None` when the base could not be resolved
///   to an allocation start — pre-flight then skips accesses through it).
pub fn preflight_launch(
    kr: &KernelReport,
    kernel: &Kernel,
    grid: [u32; 3],
    block: [u32; 3],
    param_vals: &[Option<i128>],
    param_avail: &[Option<i128>],
) -> Result<()> {
    if grid.iter().chain(&block).any(|&d| d == 0) {
        return Ok(()); // dim validation rejects this launch elsewhere
    }
    let bounds = |s: Sym| -> Itv {
        match s {
            Sym::Tid(d) => Itv::range(0, block[d as usize] as i128 - 1),
            Sym::Ntid(d) => Itv::point(block[d as usize] as i128),
            Sym::Ctaid(d) => Itv::range(0, grid[d as usize] as i128 - 1),
            Sym::Nctaid(d) => Itv::point(grid[d as usize] as i128),
            Sym::CtaidNtid(d) => {
                Itv::range(0, (grid[d as usize] as i128 - 1) * block[d as usize] as i128)
            }
            Sym::Param(i) => param_vals
                .get(i as usize)
                .copied()
                .flatten()
                .map(Itv::point)
                .unwrap_or_else(|| {
                    kr.param_itv.get(i as usize).copied().unwrap_or(Itv::TOP)
                }),
            Sym::Opaque(q) => {
                kr.opaques.get(q as usize).map(|o| o.itv).unwrap_or(Itv::TOP)
            }
        }
    };
    for a in &kr.accesses {
        // Only accesses that provably execute, with exact offset forms
        // whose every symbol is concrete at this launch, can be *proven*
        // out of bounds.
        if !a.provable || a.slop != Itv::ZERO {
            continue;
        }
        let avail: i128 = match a.prov {
            Prov::Shared => kernel.shared_bytes as i128,
            Prov::Param(i) => match param_avail.get(i as usize).copied().flatten() {
                Some(n) => n,
                None => continue,
            },
            Prov::Unknown => continue,
        };
        let concrete = a.off.terms.keys().all(|s| match s {
            Sym::Opaque(_) => false,
            Sym::Param(i) => param_vals.get(*i as usize).copied().flatten().is_some(),
            _ => true,
        });
        if !concrete {
            continue;
        }
        let les = le_forms(&a.guards);
        // A guard that is infeasible at these dims/args (e.g. `i < n`
        // with n = 0) means the access never executes here.
        if les.iter().any(|e| e.eval(&bounds).lo > 0) {
            continue;
        }
        let hi = upper_bound(&a.off, &les, &bounds, DEPTH);
        let lo = lower_bound(&a.off, &les, &bounds, DEPTH);
        let end = hi.saturating_add(a.width as i128);
        if lo < 0 || end > avail {
            let region = match a.prov {
                Prov::Shared => "the shared window".to_string(),
                Prov::Param(i) => format!(
                    "the allocation behind param `{}`",
                    kernel
                        .params
                        .get(i as usize)
                        .map(|p| p.name.as_str())
                        .unwrap_or("?")
                ),
                Prov::Unknown => unreachable!(),
            };
            let diag = Diagnostic {
                severity: Severity::Error,
                kernel: kr.name.clone(),
                path: a.path.clone(),
                analysis: "bounds",
                message: format!(
                    "{} of {} byte(s) at offset `{}` reaches bytes [{lo}, {end}) of \
                     {region} ({avail} bytes) at grid {:?} block {:?}",
                    a.kind.verb(),
                    a.width,
                    a.off,
                    grid,
                    block,
                ),
            };
            return Err(HetError::StaticFault {
                kernel: kr.name.clone(),
                stmt: a.path.to_string(),
                diag: diag.to_string(),
            });
        }
    }
    Ok(())
}
