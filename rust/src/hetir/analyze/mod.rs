//! Static analysis of hetIR kernels (DESIGN.md §12).
//!
//! The paper's binary-compatibility promise means undefined behavior one
//! backend tolerates silently (an OOB global store, a benign-under-lockstep
//! shared-memory race, an ordered atomic across shards) is a portability
//! and migration hazard on every other backend — so it is caught **once,
//! statically, at the IR layer**. `analyze_module` runs after
//! `verify_module` at module load and produces an [`AnalysisReport`]
//! cached per module beside the JIT cache:
//!
//! * an **affine range engine** ([`affine`], [`engine`]) giving every
//!   integer register a symbolic affine form over thread coordinates and
//!   kernel parameters,
//! * a **shared-memory race detector** ([`race`]) over barrier intervals,
//! * **bounds checking** ([`bounds`]) — symbolic at load, instantiated
//!   with concrete dims/args at launch pre-flight,
//! * **uninitialized-read detection** (in [`engine`], must-init meet at
//!   joins).
//!
//! Analysis never changes codegen, migration, or suspension metadata — it
//! only reads the IR and gates launches through
//! `LaunchBuilder::analysis(Strict | Warn | Off)`.

pub mod affine;
mod bounds;
mod engine;
mod race;

pub use bounds::preflight_launch;

use crate::hetir::module::{Kernel, Module};
use crate::hetir::types::AddrSpace;
use affine::{Affine, Guard, Itv, Sym};
use std::fmt;
use std::sync::Arc;
use std::time::Instant;

/// One segment of a statement path: which arm of which block statement.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SegKind {
    Body,
    Then,
    Else,
    Cond,
}

impl SegKind {
    fn name(self) -> &'static str {
        match self {
            SegKind::Body => "body",
            SegKind::Then => "then",
            SegKind::Else => "else",
            SegKind::Cond => "cond",
        }
    }
}

/// A path to a statement inside a kernel body, e.g. `body[3].then[1]` —
/// the uniform location language shared by verifier errors and analysis
/// diagnostics.
#[derive(Debug, Clone, PartialEq, Eq, Hash, Default)]
pub struct StmtPath(pub Vec<(SegKind, u32)>);

impl fmt::Display for StmtPath {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0.is_empty() {
            return write!(f, "<kernel>");
        }
        for (i, (kind, idx)) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, ".")?;
            }
            write!(f, "{}[{}]", kind.name(), idx)?;
        }
        Ok(())
    }
}

/// Diagnostic severity. `Warn` mode prints `Warning` and above at module
/// load; `Strict` mode refuses to launch kernels carrying any.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Info,
    Warning,
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Severity::Info => "info",
            Severity::Warning => "warning",
            Severity::Error => "error",
        })
    }
}

/// A structured analysis finding.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub severity: Severity,
    pub kernel: String,
    pub path: StmtPath,
    /// Which analysis produced it: `"race"`, `"bounds"`, or `"uninit"`.
    pub analysis: &'static str,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "hetgpu: [{}] {} in `{}` at {}: {}",
            self.severity, self.analysis, self.kernel, self.path, self.message
        )
    }
}

/// How a memory instruction touches its location.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessKind {
    Read,
    Write,
    Atomic,
}

impl AccessKind {
    fn verb(self) -> &'static str {
        match self {
            AccessKind::Read => "read",
            AccessKind::Write => "write",
            AccessKind::Atomic => "atomic",
        }
    }
}

/// Which memory region an access offset is relative to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Prov {
    /// Offset from the pointer passed as kernel parameter `i`.
    Param(u32),
    /// Offset into the kernel's static shared-memory window.
    Shared,
    /// Base pointer could not be traced — bounds checking skips it, the
    /// race detector treats it as overlapping everything in its space.
    Unknown,
}

/// One memory access, fully symbolic: the engine records these once per
/// kernel; the race detector pairs them up and launch pre-flight
/// instantiates them against concrete dims/args.
#[derive(Debug, Clone)]
pub struct Access {
    pub kind: AccessKind,
    pub space: AddrSpace,
    pub prov: Prov,
    /// Byte offset from the region base as an affine form...
    pub off: Affine,
    /// ...plus this much interval slop (`[0,0]` = exact).
    pub slop: Itv,
    /// Access width in bytes.
    pub width: u64,
    /// Path conditions that hold whenever the access executes.
    pub guards: Vec<Guard>,
    /// Canonical barrier-interval label (accesses with equal labels can
    /// be concurrent for two threads of one block).
    pub label: u32,
    /// Enclosing loop ids, outermost first.
    pub loops: Vec<u32>,
    pub path: StmtPath,
    /// False when the access sits under a condition the engine could not
    /// translate into guards — pre-flight then cannot prove the access
    /// executes and stays silent.
    pub provable: bool,
    /// Atomic op that does not commute (Exch/Cas).
    pub ordered_atomic: bool,
}

/// A loop-widened unknown: its interval, the loop that minted it, and
/// whether the underlying register is block-uniform (uniform loop
/// variables are *shared* between the two thread instances of a race
/// query; varying ones are renamed apart).
#[derive(Debug, Clone, Copy)]
pub struct OpaqueInfo {
    pub itv: Itv,
    pub loop_id: u32,
    pub uniform: bool,
}

/// Per-kernel analysis result.
#[derive(Debug, Clone)]
pub struct KernelReport {
    pub name: String,
    pub diags: Vec<Diagnostic>,
    pub accesses: Vec<Access>,
    pub opaques: Vec<OpaqueInfo>,
    /// Loop nesting: `loop_parent[l]` is the id of the loop enclosing `l`.
    pub loop_parent: Vec<Option<u32>>,
    /// `(tail label, head label, loop id)` — accesses in the tail
    /// interval of an iteration can race accesses in the head interval of
    /// the next one. Labels are canonical.
    pub backedges: Vec<(u32, u32, u32)>,
    /// Which `threadIdx` dimensions the kernel reads at all (unreferenced
    /// dimensions are assumed to have extent 1 for distinctness
    /// arguments; see DESIGN.md §12).
    pub tid_dims: [bool; 3],
    /// Type-derived range of each scalar parameter (load-time bounds).
    pub param_itv: Vec<Itv>,
    pub analysis_nanos: u64,
}

impl KernelReport {
    /// Highest diagnostic severity, if any.
    pub fn worst(&self) -> Option<Severity> {
        self.diags.iter().map(|d| d.severity).max()
    }

    /// Load-time per-symbol bounds: coordinates are only sign-bounded,
    /// parameters carry their type range, opaques their widened interval.
    pub fn load_bounds(&self) -> impl Fn(Sym) -> Itv + '_ {
        move |s| match s {
            Sym::Tid(_) | Sym::Ctaid(_) | Sym::CtaidNtid(_) => Itv::range(0, affine::POS_INF),
            Sym::Ntid(_) | Sym::Nctaid(_) => Itv::range(1, affine::POS_INF),
            Sym::Param(i) => self.param_itv.get(i as usize).copied().unwrap_or(Itv::TOP),
            Sym::Opaque(q) => {
                self.opaques.get(q as usize).map(|o| o.itv).unwrap_or(Itv::TOP)
            }
        }
    }
}

/// Whole-module analysis result, cached per loaded module.
#[derive(Debug, Clone, Default)]
pub struct AnalysisReport {
    pub kernels: Vec<KernelReport>,
}

impl AnalysisReport {
    pub fn kernel(&self, name: &str) -> Option<&KernelReport> {
        self.kernels.iter().find(|k| k.name == name)
    }

    /// `(info, warning, error)` diagnostic counts.
    pub fn diag_counts(&self) -> (u64, u64, u64) {
        let mut c = (0, 0, 0);
        for k in &self.kernels {
            for d in &k.diags {
                match d.severity {
                    Severity::Info => c.0 += 1,
                    Severity::Warning => c.1 += 1,
                    Severity::Error => c.2 += 1,
                }
            }
        }
        c
    }

    pub fn total_nanos(&self) -> u64 {
        self.kernels.iter().map(|k| k.analysis_nanos).sum()
    }
}

/// Analyze every kernel of a verified module.
pub fn analyze_module(m: &Module) -> AnalysisReport {
    AnalysisReport { kernels: m.kernels.iter().map(analyze_kernel).collect() }
}

/// Analyze one kernel: run the affine engine, then the race detector and
/// the load-time bounds pass over its access set.
pub fn analyze_kernel(k: &Kernel) -> KernelReport {
    let t0 = Instant::now();
    let mut report = engine::run(k);
    race::check(&mut report);
    bounds::load_time_check(&mut report, k);
    report.analysis_nanos = t0.elapsed().as_nanos() as u64;
    report
}

/// How much the analyzer is allowed to gate (per launch; default from
/// `HETGPU_ANALYZE`, overridden per-launch by `LaunchBuilder::analysis`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AnalysisLevel {
    /// Any load-time diagnostic of `Warning` severity or above fails the
    /// launch, in addition to everything `Warn` rejects.
    Strict,
    /// Load-time diagnostics print to stderr; a *provable* OOB at the
    /// requested dims/args still fails pre-flight (there is no
    /// configuration in which running it is correct). The default.
    #[default]
    Warn,
    /// No analysis, no pre-flight: the runtime fail-closed paths
    /// (device-level OOB faults, `OrderedAtomic`) remain as defense in
    /// depth.
    Off,
}

/// Parse an `HETGPU_ANALYZE` value. Malformed input returns the default
/// plus the warning to print — the `HETGPU_SIM_THREADS` contract.
pub fn parse_analysis_level(raw: &str) -> (AnalysisLevel, Option<String>) {
    match raw.trim().to_ascii_lowercase().as_str() {
        "strict" => (AnalysisLevel::Strict, None),
        "warn" => (AnalysisLevel::Warn, None),
        "off" => (AnalysisLevel::Off, None),
        _ => (
            AnalysisLevel::Warn,
            Some(format!(
                "hetgpu: HETGPU_ANALYZE={raw:?} is not one of strict|warn|off; \
                 falling back to warn"
            )),
        ),
    }
}

impl AnalysisLevel {
    /// Level from `HETGPU_ANALYZE`, warning once on malformed input.
    pub fn from_env() -> AnalysisLevel {
        match std::env::var("HETGPU_ANALYZE") {
            Ok(raw) => {
                let (level, warning) = parse_analysis_level(&raw);
                if let Some(msg) = warning {
                    warn_once(&msg);
                }
                level
            }
            Err(_) => AnalysisLevel::Warn,
        }
    }
}

/// Print a warning to stderr at most once per distinct message for the
/// process lifetime — shared by every parse-warn-default env knob.
pub(crate) fn warn_once(msg: &str) {
    use std::collections::HashSet;
    use std::sync::{Mutex, OnceLock};
    static SEEN: OnceLock<Mutex<HashSet<String>>> = OnceLock::new();
    let seen = SEEN.get_or_init(|| Mutex::new(HashSet::new()));
    if seen.lock().unwrap().insert(msg.to_string()) {
        eprintln!("{msg}");
    }
}

/// Shared handle type for the cached report.
pub type SharedReport = Arc<AnalysisReport>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stmt_path_renders_like_the_issue_example() {
        let p = StmtPath(vec![(SegKind::Body, 3), (SegKind::Then, 1)]);
        assert_eq!(p.to_string(), "body[3].then[1]");
        assert_eq!(StmtPath::default().to_string(), "<kernel>");
    }

    #[test]
    fn analysis_level_parses_with_warn_fallback() {
        assert_eq!(parse_analysis_level("strict"), (AnalysisLevel::Strict, None));
        assert_eq!(parse_analysis_level(" WARN "), (AnalysisLevel::Warn, None));
        assert_eq!(parse_analysis_level("off"), (AnalysisLevel::Off, None));
        let (level, warning) = parse_analysis_level("paranoid");
        assert_eq!(level, AnalysisLevel::Warn);
        let msg = warning.expect("malformed value must warn");
        assert!(msg.contains("HETGPU_ANALYZE"), "warning must name the variable: {msg}");
        assert!(msg.contains("paranoid"));
    }
}
