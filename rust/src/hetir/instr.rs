//! hetIR instruction set.
//!
//! hetIR is an SPMD, *structured-control-flow* IR (paper §4.1):
//!
//! * Threads are conceptually independent; no warp size is baked in.
//! * Divergence is expressed with structured `If`/`While` regions whose
//!   reconvergence points are implicit in the structure — SIMT backends map
//!   these to hardware divergence (mask stacks / SSY-SYNC), MIMD backends to
//!   real branches (scalar mode) or vector masks (vectorized-warp mode).
//! * Synchronization is explicit: `Bar` is a block-wide barrier, and every
//!   barrier is a **safe suspension point** for checkpoint/migration.
//! * Team-level operations (`Vote`, `Ballot`, `Shfl`) are virtualized: the
//!   backend implements them with warp intrinsics where the hardware has
//!   them, and with reductions/staging buffers where it does not.
//!
//! Registers are typed virtual registers with PTX-like assign-many
//! semantics (not strict SSA) — this keeps the frontend simple and makes a
//! snapshot literally "the register file", as the paper's state
//! representation requires.

use super::types::{AddrSpace, Scalar, Value};
use std::fmt;

/// A virtual register id. Each kernel owns a flat, typed register file.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Reg(pub u32);

impl fmt::Display for Reg {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "%r{}", self.0)
    }
}

/// An instruction operand: a register or an immediate constant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Operand {
    Reg(Reg),
    Imm(Value),
}

impl Operand {
    /// The register, if this operand is one.
    pub fn reg(&self) -> Option<Reg> {
        match self {
            Operand::Reg(r) => Some(*r),
            Operand::Imm(_) => None,
        }
    }
}

impl From<Reg> for Operand {
    fn from(r: Reg) -> Self {
        Operand::Reg(r)
    }
}

impl From<Value> for Operand {
    fn from(v: Value) -> Self {
        Operand::Imm(v)
    }
}

/// Grid/block index dimension.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dim {
    X,
    Y,
    Z,
}

impl Dim {
    pub fn index(self) -> usize {
        match self {
            Dim::X => 0,
            Dim::Y => 1,
            Dim::Z => 2,
        }
    }
    pub fn from_index(i: usize) -> Dim {
        match i {
            0 => Dim::X,
            1 => Dim::Y,
            _ => Dim::Z,
        }
    }
}

impl fmt::Display for Dim {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Dim::X => write!(f, "x"),
            Dim::Y => write!(f, "y"),
            Dim::Z => write!(f, "z"),
        }
    }
}

/// Special (read-only) per-thread registers, CUDA-style.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SpecialReg {
    /// Thread index within the block (u32).
    ThreadIdx(Dim),
    /// Block index within the grid (u32).
    BlockIdx(Dim),
    /// Block dimensions (u32).
    BlockDim(Dim),
    /// Grid dimensions (u32).
    GridDim(Dim),
    /// Convenience: `blockIdx*blockDim + threadIdx` (u32) — the paper's
    /// `GET_GLOBAL_ID` opcode.
    GlobalId(Dim),
}

/// Binary arithmetic / bitwise operations. The `ty` on the instruction
/// selects the interpretation (signed/unsigned/float).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    /// Division. Integer division by zero is a device fault (as on real
    /// GPUs it yields undefined results; we choose to trap in the sim).
    Div,
    Rem,
    Min,
    Max,
    And,
    Or,
    Xor,
    Shl,
    /// Shift right: arithmetic for signed `ty`, logical for unsigned.
    Shr,
}

/// Unary operations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UnOp {
    Neg,
    /// Bitwise not for ints, logical not for predicates.
    Not,
    Abs,
    Sqrt,
    Rsqrt,
    Exp,
    Log,
    Sin,
    Cos,
    /// Population count (int → u32).
    Popc,
}

/// Comparison predicates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CmpOp {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

/// Atomic read-modify-write operations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AtomOp {
    Add,
    Min,
    Max,
    Exch,
    /// Compare-and-swap: `val` is the compare value, `val2` the new value.
    Cas,
    And,
    Or,
    Xor,
}

impl AtomOp {
    /// Whether the op's combine function commutes: applying any multiset
    /// of updates to a location yields the same final integer value in
    /// every order (Add/Min/Max/And/Or/Xor). Exch and Cas *observe or
    /// replace* the prior value, so their effect depends on where they
    /// land in the update order — they are **ordered** ops. This is the
    /// hardware-invariant classification the cross-shard atomics protocol
    /// keys on: commutative ops journal and replay across shards; ordered
    /// ops fail closed under sharded execution (see `delta::journal`).
    /// Float `Add` commutes but is not associative, so its final *bits*
    /// remain arrival-order-dependent — exactly as on real GPUs.
    pub fn commutes(&self) -> bool {
        !matches!(self, AtomOp::Exch | AtomOp::Cas)
    }

    /// Text-assembly mnemonic (shared by the printer, parser errors, and
    /// the ordered-atomic fault message).
    pub fn mnemonic(&self) -> &'static str {
        match self {
            AtomOp::Add => "ADD",
            AtomOp::Min => "MIN",
            AtomOp::Max => "MAX",
            AtomOp::Exch => "EXCH",
            AtomOp::Cas => "CAS",
            AtomOp::And => "AND",
            AtomOp::Or => "OR",
            AtomOp::Xor => "XOR",
        }
    }
}

/// Warp/team vote flavors.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum VoteKind {
    Any,
    All,
}

/// Shuffle flavors (CUDA `__shfl_*_sync` family).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ShflKind {
    /// Read from absolute lane `lane`.
    Idx,
    /// Read from `self_lane + lane`.
    Down,
    /// Read from `self_lane - lane`.
    Up,
    /// Read from `self_lane ^ lane`.
    Xor,
}

/// A memory address expression: `[%base + %index * scale + disp]`.
///
/// Keeping the index/scale explicit (instead of pre-folding into the base)
/// lets the Tensix backend turn strided loads into DMA descriptors and the
/// SIMT cost model detect coalesced access patterns.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Address {
    /// Base pointer register (must be `Ptr(space)`-typed matching the op).
    pub base: Reg,
    /// Optional index register (integer-typed).
    pub index: Option<Reg>,
    /// Byte scale applied to the index.
    pub scale: u32,
    /// Constant byte displacement.
    pub disp: i64,
}

impl Address {
    pub fn base(base: Reg) -> Address {
        Address { base, index: None, scale: 1, disp: 0 }
    }
    pub fn indexed(base: Reg, index: Reg, scale: u32) -> Address {
        Address { base, index: Some(index), scale, disp: 0 }
    }
    pub fn with_disp(mut self, disp: i64) -> Address {
        self.disp = disp;
        self
    }
}

/// Memory fence scope.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FenceScope {
    /// Orders accesses for threads in the same block.
    Block,
    /// Orders accesses device-wide.
    Device,
}

/// A straight-line hetIR instruction (control flow lives in [`super::module::Stmt`]).
#[derive(Debug, Clone, PartialEq)]
pub enum Inst {
    /// Read a special register into `dst`.
    Special { dst: Reg, kind: SpecialReg },
    /// Copy/materialize a value.
    Mov { dst: Reg, src: Operand },
    /// `dst = a <op> b` in type `ty`.
    Bin { op: BinOp, ty: Scalar, dst: Reg, a: Operand, b: Operand },
    /// `dst = <op> a` in type `ty`.
    Un { op: UnOp, ty: Scalar, dst: Reg, a: Operand },
    /// Fused multiply-add: `dst = a * b + c` (float only).
    Fma { ty: Scalar, dst: Reg, a: Operand, b: Operand, c: Operand },
    /// `dst(pred) = a <cmp> b` comparing in type `ty`.
    Cmp { op: CmpOp, ty: Scalar, dst: Reg, a: Operand, b: Operand },
    /// `dst = cond ? a : b`.
    Sel { dst: Reg, cond: Operand, a: Operand, b: Operand },
    /// Convert `src` (of type `from`) to `to`, storing in `dst`.
    Cvt { from: Scalar, to: Scalar, dst: Reg, src: Operand },
    /// Pointer arithmetic: `dst(ptr) = base + index*scale + disp` — kept
    /// distinct from `Bin` so pointer-typed dataflow stays visible to the
    /// migration pointer-rebasing machinery.
    PtrAdd { dst: Reg, addr: Address },
    /// Load `ty` from `space` at `addr`.
    Ld { space: AddrSpace, ty: Scalar, dst: Reg, addr: Address },
    /// Store `ty` to `space` at `addr`.
    St { space: AddrSpace, ty: Scalar, addr: Address, val: Operand },
    /// Atomic RMW. `dst` receives the old value if present.
    /// For `Cas`, `val` is the expected value and `val2` the replacement.
    Atom {
        op: AtomOp,
        space: AddrSpace,
        ty: Scalar,
        dst: Option<Reg>,
        addr: Address,
        val: Operand,
        val2: Option<Operand>,
    },
    /// Block-wide barrier. `id` is assigned by the segmenter pass and names
    /// the suspension point / migration segment boundary.
    Bar { id: u32 },
    /// Memory fence.
    Fence { scope: FenceScope },
    /// Team vote: `dst(pred) = any/all(pred over team)`.
    Vote { kind: VoteKind, dst: Reg, src: Operand },
    /// Team ballot: `dst(u32) = bitmask of lanes where src is true`.
    Ballot { dst: Reg, src: Operand },
    /// Team shuffle: `dst = value of `val` in the lane selected by `kind`/`lane``.
    Shfl { kind: ShflKind, ty: Scalar, dst: Reg, val: Operand, lane: Operand },
    /// Simple xorshift PRNG step: `dst = xorshift32(state)`; `state` is
    /// updated in place. Virtualized so that every backend produces the
    /// *same* random sequence — required for bit-reproducible migration of
    /// the Monte-Carlo workload across architectures.
    Rng { dst: Reg, state: Reg },
    /// Abort the kernel with an error code (device-side assert).
    Trap { code: u32 },
}

impl Inst {
    /// The register this instruction defines, if any.
    pub fn def(&self) -> Option<Reg> {
        match self {
            Inst::Special { dst, .. }
            | Inst::Mov { dst, .. }
            | Inst::Bin { dst, .. }
            | Inst::Un { dst, .. }
            | Inst::Fma { dst, .. }
            | Inst::Cmp { dst, .. }
            | Inst::Sel { dst, .. }
            | Inst::Cvt { dst, .. }
            | Inst::PtrAdd { dst, .. }
            | Inst::Ld { dst, .. }
            | Inst::Vote { dst, .. }
            | Inst::Ballot { dst, .. }
            | Inst::Shfl { dst, .. }
            | Inst::Rng { dst, .. } => Some(*dst),
            Inst::Atom { dst, .. } => *dst,
            Inst::St { .. } | Inst::Bar { .. } | Inst::Fence { .. } | Inst::Trap { .. } => None,
        }
    }

    /// Collect the registers this instruction reads.
    pub fn uses(&self, out: &mut Vec<Reg>) {
        fn op(o: &Operand, out: &mut Vec<Reg>) {
            if let Operand::Reg(r) = o {
                out.push(*r);
            }
        }
        fn addr(a: &Address, out: &mut Vec<Reg>) {
            out.push(a.base);
            if let Some(i) = a.index {
                out.push(i);
            }
        }
        match self {
            Inst::Special { .. } | Inst::Bar { .. } | Inst::Fence { .. } | Inst::Trap { .. } => {}
            Inst::Mov { src, .. } => op(src, out),
            Inst::Bin { a, b, .. } | Inst::Cmp { a, b, .. } => {
                op(a, out);
                op(b, out);
            }
            Inst::Un { a, .. } => op(a, out),
            Inst::Fma { a, b, c, .. } => {
                op(a, out);
                op(b, out);
                op(c, out);
            }
            Inst::Sel { cond, a, b, .. } => {
                op(cond, out);
                op(a, out);
                op(b, out);
            }
            Inst::Cvt { src, .. } => op(src, out),
            Inst::PtrAdd { addr: a, .. } => addr(a, out),
            Inst::Ld { addr: a, .. } => addr(a, out),
            Inst::St { addr: a, val, .. } => {
                addr(a, out);
                op(val, out);
            }
            Inst::Atom { addr: a, val, val2, .. } => {
                addr(a, out);
                op(val, out);
                if let Some(v2) = val2 {
                    op(v2, out);
                }
            }
            Inst::Vote { src, .. } | Inst::Ballot { src, .. } => op(src, out),
            Inst::Shfl { val, lane, .. } => {
                op(val, out);
                op(lane, out);
            }
            Inst::Rng { state, .. } => out.push(*state),
        }
    }

    /// True if the instruction has side effects beyond its `def` (memory
    /// writes, barriers, traps, RNG state update) — these survive DCE.
    pub fn has_side_effect(&self) -> bool {
        matches!(
            self,
            Inst::St { .. }
                | Inst::Atom { .. }
                | Inst::Bar { .. }
                | Inst::Fence { .. }
                | Inst::Trap { .. }
                | Inst::Rng { .. }
        )
    }

    /// True if this instruction communicates across the team (its result
    /// depends on other threads) — such instructions can never be folded or
    /// hoisted thread-locally.
    pub fn is_team_op(&self) -> bool {
        matches!(
            self,
            Inst::Vote { .. } | Inst::Ballot { .. } | Inst::Shfl { .. } | Inst::Bar { .. }
        )
    }

    /// The result type this instruction produces given the kernel's
    /// register typing rules, if statically determined by the opcode alone.
    pub fn result_scalar(&self) -> Option<Scalar> {
        match self {
            Inst::Cmp { .. } | Inst::Vote { .. } => Some(Scalar::Pred),
            Inst::Ballot { .. } => Some(Scalar::U32),
            Inst::Special { .. } => Some(Scalar::U32),
            Inst::Cvt { to, .. } => Some(*to),
            Inst::Bin { ty, .. } | Inst::Un { ty, .. } | Inst::Fma { ty, .. } => Some(*ty),
            Inst::Ld { ty, .. } | Inst::Atom { ty, .. } | Inst::Shfl { ty, .. } => Some(*ty),
            Inst::Rng { .. } => Some(Scalar::U32),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn def_and_uses() {
        let i = Inst::Bin {
            op: BinOp::Add,
            ty: Scalar::F32,
            dst: Reg(2),
            a: Operand::Reg(Reg(0)),
            b: Operand::Reg(Reg(1)),
        };
        assert_eq!(i.def(), Some(Reg(2)));
        let mut u = vec![];
        i.uses(&mut u);
        assert_eq!(u, vec![Reg(0), Reg(1)]);
    }

    #[test]
    fn store_has_side_effect_and_no_def() {
        let st = Inst::St {
            space: AddrSpace::Global,
            ty: Scalar::F32,
            addr: Address::base(Reg(0)),
            val: Operand::Reg(Reg(1)),
        };
        assert!(st.has_side_effect());
        assert_eq!(st.def(), None);
        let mut u = vec![];
        st.uses(&mut u);
        assert_eq!(u, vec![Reg(0), Reg(1)]);
    }

    #[test]
    fn team_ops_flagged() {
        let v = Inst::Vote { kind: VoteKind::Any, dst: Reg(1), src: Operand::Reg(Reg(0)) };
        assert!(v.is_team_op());
        assert_eq!(v.result_scalar(), Some(Scalar::Pred));
    }

    #[test]
    fn address_constructors() {
        let a = Address::indexed(Reg(0), Reg(1), 4).with_disp(8);
        assert_eq!(a.scale, 4);
        assert_eq!(a.disp, 8);
        assert_eq!(a.index, Some(Reg(1)));
    }

    #[test]
    fn atom_cas_uses_both_values() {
        let i = Inst::Atom {
            op: AtomOp::Cas,
            space: AddrSpace::Global,
            ty: Scalar::U32,
            dst: Some(Reg(3)),
            addr: Address::base(Reg(0)),
            val: Operand::Reg(Reg(1)),
            val2: Some(Operand::Reg(Reg(2))),
        };
        let mut u = vec![];
        i.uses(&mut u);
        assert_eq!(u, vec![Reg(0), Reg(1), Reg(2)]);
        assert_eq!(i.def(), Some(Reg(3)));
    }
}
