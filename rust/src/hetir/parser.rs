//! hetIR text-assembly parser — the load half of the interchange format.
//!
//! Accepts exactly the grammar [`super::printer`] emits (plus flexible
//! whitespace and `//` comments). The runtime calls this when loading a
//! `.hetir` module from disk; the roundtrip property is tested below and in
//! the property suite.

use super::instr::*;
use super::module::{Kernel, Module, Param, Stmt};
use super::types::{AddrSpace, Scalar, Type, Value};
use crate::error::{HetError, Result};

/// Token-level cursor over the input text.
struct Cursor<'a> {
    src: &'a str,
    pos: usize,
    line: usize,
}

impl<'a> Cursor<'a> {
    fn new(src: &'a str) -> Self {
        Cursor { src, pos: 0, line: 1 }
    }

    fn err(&self, msg: impl Into<String>) -> HetError {
        HetError::IrParse { line: self.line, msg: msg.into() }
    }

    fn skip_ws(&mut self) {
        let bytes = self.src.as_bytes();
        while self.pos < bytes.len() {
            let c = bytes[self.pos];
            if c == b'\n' {
                self.line += 1;
                self.pos += 1;
            } else if c.is_ascii_whitespace() {
                self.pos += 1;
            } else if c == b'/' && bytes.get(self.pos + 1) == Some(&b'/') {
                while self.pos < bytes.len() && bytes[self.pos] != b'\n' {
                    self.pos += 1;
                }
            } else {
                break;
            }
        }
    }

    fn eof(&mut self) -> bool {
        self.skip_ws();
        self.pos >= self.src.len()
    }

    fn peek(&mut self) -> Option<char> {
        self.skip_ws();
        self.src[self.pos..].chars().next()
    }

    /// Consume `tok` if it is next; returns whether it was consumed.
    fn eat(&mut self, tok: &str) -> bool {
        self.skip_ws();
        if self.src[self.pos..].starts_with(tok) {
            self.pos += tok.len();
            true
        } else {
            false
        }
    }

    fn expect(&mut self, tok: &str) -> Result<()> {
        if self.eat(tok) {
            Ok(())
        } else {
            let rest: String = self.src[self.pos..].chars().take(20).collect();
            Err(self.err(format!("expected `{tok}`, found `{rest}`")))
        }
    }

    /// Read an identifier-like word ([A-Za-z0-9_.$]+).
    fn word(&mut self) -> Result<String> {
        self.skip_ws();
        let start = self.pos;
        let bytes = self.src.as_bytes();
        while self.pos < bytes.len() {
            let c = bytes[self.pos] as char;
            if c.is_ascii_alphanumeric() || c == '_' || c == '.' || c == '$' {
                self.pos += 1;
            } else {
                break;
            }
        }
        if self.pos == start {
            let rest: String = self.src[self.pos..].chars().take(10).collect();
            return Err(self.err(format!("expected word, found `{rest}`")));
        }
        Ok(self.src[start..self.pos].to_string())
    }

    /// Parse `%rN`.
    fn reg(&mut self) -> Result<Reg> {
        self.expect("%r")?;
        let start = self.pos;
        let bytes = self.src.as_bytes();
        while self.pos < bytes.len() && bytes[self.pos].is_ascii_digit() {
            self.pos += 1;
        }
        if self.pos == start {
            return Err(self.err("expected register number after %r"));
        }
        let n: u32 =
            self.src[start..self.pos].parse().map_err(|e| self.err(format!("bad reg: {e}")))?;
        Ok(Reg(n))
    }

    /// Parse a signed integer literal (used for displacements / ids).
    fn int(&mut self) -> Result<i64> {
        self.skip_ws();
        let start = self.pos;
        let bytes = self.src.as_bytes();
        if self.pos < bytes.len() && (bytes[self.pos] == b'-' || bytes[self.pos] == b'+') {
            self.pos += 1;
        }
        if self.src[self.pos..].starts_with("0x") {
            self.pos += 2;
            while self.pos < bytes.len() && bytes[self.pos].is_ascii_hexdigit() {
                self.pos += 1;
            }
            let text = &self.src[start..self.pos];
            let neg = text.starts_with('-');
            let digits = text.trim_start_matches(['-', '+']).trim_start_matches("0x");
            let v = u64::from_str_radix(digits, 16)
                .map_err(|e| self.err(format!("bad hex int: {e}")))? as i64;
            return Ok(if neg { -v } else { v });
        }
        while self.pos < bytes.len() && bytes[self.pos].is_ascii_digit() {
            self.pos += 1;
        }
        if self.pos == start {
            return Err(self.err("expected integer"));
        }
        self.src[start..self.pos].parse().map_err(|e| self.err(format!("bad int: {e}")))
    }

    /// Parse a type: `pred|s32|u32|s64|u64|f32|ptr<global>|ptr<shared>`.
    fn ty(&mut self) -> Result<Type> {
        if self.eat("ptr<") {
            let t = if self.eat("global") {
                Type::PTR_GLOBAL
            } else if self.eat("shared") {
                Type::PTR_SHARED
            } else {
                return Err(self.err("expected global|shared in ptr<>"));
            };
            self.expect(">")?;
            return Ok(t);
        }
        let w = self.word()?;
        Ok(match w.as_str() {
            "pred" => Type::PRED,
            "s32" => Type::I32,
            "u32" => Type::U32,
            "s64" => Type::I64,
            "u64" => Type::U64,
            "f32" => Type::F32,
            other => return Err(self.err(format!("unknown type `{other}`"))),
        })
    }

    /// Parse an operand: register or typed immediate.
    fn operand(&mut self) -> Result<Operand> {
        self.skip_ws();
        if self.src[self.pos..].starts_with("%r") {
            return Ok(Operand::Reg(self.reg()?));
        }
        if self.eat("true") {
            return Ok(Operand::Imm(Value::pred(true)));
        }
        if self.eat("false") {
            return Ok(Operand::Imm(Value::pred(false)));
        }
        // float hex form: 0f<8 hex digits>:f32
        if self.src[self.pos..].starts_with("0f") {
            self.pos += 2;
            let start = self.pos;
            let bytes = self.src.as_bytes();
            while self.pos < bytes.len() && bytes[self.pos].is_ascii_hexdigit() {
                self.pos += 1;
            }
            let bits = u32::from_str_radix(&self.src[start..self.pos], 16)
                .map_err(|e| self.err(format!("bad float bits: {e}")))?;
            self.expect(":f32")?;
            return Ok(Operand::Imm(Value { bits: bits as u64, ty: Type::F32 }));
        }
        let n = self.int()?;
        self.expect(":")?;
        let ty = self.ty()?;
        let v = match ty {
            Type::Scalar(Scalar::I32) => Value::i32(n as i32),
            Type::Scalar(Scalar::U32) => Value::u32(n as u32),
            Type::Scalar(Scalar::I64) => Value::i64(n),
            Type::Scalar(Scalar::U64) => Value::u64(n as u64),
            Type::Scalar(Scalar::F32) => Value::f32(n as f32),
            Type::Scalar(Scalar::Pred) => Value::pred(n != 0),
            Type::Ptr(space) => Value::ptr(n as u64, space),
        };
        Ok(Operand::Imm(v))
    }

    /// Parse `[%base (+ %idx*scale)? (+ disp)?]`.
    fn address(&mut self) -> Result<Address> {
        self.expect("[")?;
        let base = self.reg()?;
        let mut addr = Address::base(base);
        while self.eat("+") {
            self.skip_ws();
            if self.src[self.pos..].starts_with("%r") {
                let idx = self.reg()?;
                self.expect("*")?;
                let scale = self.int()? as u32;
                addr.index = Some(idx);
                addr.scale = scale;
            } else {
                addr.disp = self.int()?;
            }
        }
        self.expect("]")?;
        Ok(addr)
    }
}

fn scalar_of(w: &str, c: &Cursor) -> Result<Scalar> {
    Scalar::from_suffix(w).ok_or_else(|| c.err(format!("unknown scalar suffix `{w}`")))
}

fn dim_of(w: &str, c: &Cursor) -> Result<Dim> {
    Ok(match w {
        "x" => Dim::X,
        "y" => Dim::Y,
        "z" => Dim::Z,
        _ => return Err(c.err(format!("bad dim `{w}`"))),
    })
}

/// Parse the mnemonic (already split on '.') into an instruction,
/// given the optional destination register.
fn parse_inst(c: &mut Cursor, dst: Option<Reg>) -> Result<Inst> {
    let m = c.word()?;
    let parts: Vec<&str> = m.split('.').collect();
    let inst = match parts[0] {
        "TID" | "CTAID" | "NTID" | "NCTAID" | "GID" => {
            let d = dim_of(parts.get(1).copied().unwrap_or(""), c)?;
            let kind = match parts[0] {
                "TID" => SpecialReg::ThreadIdx(d),
                "CTAID" => SpecialReg::BlockIdx(d),
                "NTID" => SpecialReg::BlockDim(d),
                "NCTAID" => SpecialReg::GridDim(d),
                _ => SpecialReg::GlobalId(d),
            };
            Inst::Special { dst: dst.ok_or_else(|| c.err("special needs dst"))?, kind }
        }
        "MOV" => {
            let src = c.operand()?;
            Inst::Mov { dst: dst.ok_or_else(|| c.err("MOV needs dst"))?, src }
        }
        "ADD" | "SUB" | "MUL" | "DIV" | "REM" | "MIN" | "MAX" | "AND" | "OR" | "XOR" | "SHL"
        | "SHR" => {
            let op = match parts[0] {
                "ADD" => BinOp::Add,
                "SUB" => BinOp::Sub,
                "MUL" => BinOp::Mul,
                "DIV" => BinOp::Div,
                "REM" => BinOp::Rem,
                "MIN" => BinOp::Min,
                "MAX" => BinOp::Max,
                "AND" => BinOp::And,
                "OR" => BinOp::Or,
                "XOR" => BinOp::Xor,
                "SHL" => BinOp::Shl,
                _ => BinOp::Shr,
            };
            let ty = scalar_of(parts.get(1).copied().unwrap_or(""), c)?;
            let a = c.operand()?;
            c.expect(",")?;
            let b = c.operand()?;
            Inst::Bin { op, ty, dst: dst.ok_or_else(|| c.err("bin needs dst"))?, a, b }
        }
        "NEG" | "NOT" | "ABS" | "SQRT" | "RSQRT" | "EXP" | "LOG" | "SIN" | "COS" | "POPC" => {
            let op = match parts[0] {
                "NEG" => UnOp::Neg,
                "NOT" => UnOp::Not,
                "ABS" => UnOp::Abs,
                "SQRT" => UnOp::Sqrt,
                "RSQRT" => UnOp::Rsqrt,
                "EXP" => UnOp::Exp,
                "LOG" => UnOp::Log,
                "SIN" => UnOp::Sin,
                "COS" => UnOp::Cos,
                _ => UnOp::Popc,
            };
            let ty = scalar_of(parts.get(1).copied().unwrap_or(""), c)?;
            let a = c.operand()?;
            Inst::Un { op, ty, dst: dst.ok_or_else(|| c.err("un needs dst"))?, a }
        }
        "FMA" => {
            let ty = scalar_of(parts.get(1).copied().unwrap_or(""), c)?;
            let a = c.operand()?;
            c.expect(",")?;
            let b = c.operand()?;
            c.expect(",")?;
            let v = c.operand()?;
            Inst::Fma { ty, dst: dst.ok_or_else(|| c.err("FMA needs dst"))?, a, b, c: v }
        }
        "SETP" => {
            let op = match parts.get(1).copied().unwrap_or("") {
                "EQ" => CmpOp::Eq,
                "NE" => CmpOp::Ne,
                "LT" => CmpOp::Lt,
                "LE" => CmpOp::Le,
                "GT" => CmpOp::Gt,
                "GE" => CmpOp::Ge,
                other => return Err(c.err(format!("bad cmp `{other}`"))),
            };
            let ty = scalar_of(parts.get(2).copied().unwrap_or(""), c)?;
            let a = c.operand()?;
            c.expect(",")?;
            let b = c.operand()?;
            Inst::Cmp { op, ty, dst: dst.ok_or_else(|| c.err("SETP needs dst"))?, a, b }
        }
        "SEL" => {
            let cond = c.operand()?;
            c.expect(",")?;
            let a = c.operand()?;
            c.expect(",")?;
            let b = c.operand()?;
            Inst::Sel { dst: dst.ok_or_else(|| c.err("SEL needs dst"))?, cond, a, b }
        }
        "CVT" => {
            let to = scalar_of(parts.get(1).copied().unwrap_or(""), c)?;
            let from = scalar_of(parts.get(2).copied().unwrap_or(""), c)?;
            let src = c.operand()?;
            Inst::Cvt { from, to, dst: dst.ok_or_else(|| c.err("CVT needs dst"))?, src }
        }
        "PTRADD" => {
            let addr = c.address()?;
            Inst::PtrAdd { dst: dst.ok_or_else(|| c.err("PTRADD needs dst"))?, addr }
        }
        "LD" => {
            let space = match parts.get(1).copied().unwrap_or("") {
                "GLOBAL" => AddrSpace::Global,
                "SHARED" => AddrSpace::Shared,
                other => return Err(c.err(format!("bad space `{other}`"))),
            };
            let ty = scalar_of(parts.get(2).copied().unwrap_or(""), c)?;
            let addr = c.address()?;
            Inst::Ld { space, ty, dst: dst.ok_or_else(|| c.err("LD needs dst"))?, addr }
        }
        "ST" => {
            let space = match parts.get(1).copied().unwrap_or("") {
                "GLOBAL" => AddrSpace::Global,
                "SHARED" => AddrSpace::Shared,
                other => return Err(c.err(format!("bad space `{other}`"))),
            };
            let ty = scalar_of(parts.get(2).copied().unwrap_or(""), c)?;
            let addr = c.address()?;
            c.expect(",")?;
            let val = c.operand()?;
            Inst::St { space, ty, addr, val }
        }
        "ATOM" => {
            let op = match parts.get(1).copied().unwrap_or("") {
                "ADD" => AtomOp::Add,
                "MIN" => AtomOp::Min,
                "MAX" => AtomOp::Max,
                "EXCH" => AtomOp::Exch,
                "CAS" => AtomOp::Cas,
                "AND" => AtomOp::And,
                "OR" => AtomOp::Or,
                "XOR" => AtomOp::Xor,
                other => return Err(c.err(format!("bad atomic `{other}`"))),
            };
            let space = match parts.get(2).copied().unwrap_or("") {
                "GLOBAL" => AddrSpace::Global,
                "SHARED" => AddrSpace::Shared,
                other => return Err(c.err(format!("bad space `{other}`"))),
            };
            let ty = scalar_of(parts.get(3).copied().unwrap_or(""), c)?;
            let addr = c.address()?;
            c.expect(",")?;
            let val = c.operand()?;
            let val2 = if c.eat(",") { Some(c.operand()?) } else { None };
            if op == AtomOp::Cas && val2.is_none() {
                return Err(c.err("ATOM.CAS needs two value operands"));
            }
            Inst::Atom { op, space, ty, dst, addr, val, val2 }
        }
        "BAR" => Inst::Bar { id: c.int()? as u32 },
        "FENCE" => {
            let scope = match parts.get(1).copied().unwrap_or("") {
                "BLOCK" => FenceScope::Block,
                "DEVICE" => FenceScope::Device,
                other => return Err(c.err(format!("bad fence scope `{other}`"))),
            };
            Inst::Fence { scope }
        }
        "VOTE" => {
            let kind = match parts.get(1).copied().unwrap_or("") {
                "ANY" => VoteKind::Any,
                "ALL" => VoteKind::All,
                other => return Err(c.err(format!("bad vote `{other}`"))),
            };
            let src = c.operand()?;
            Inst::Vote { kind, dst: dst.ok_or_else(|| c.err("VOTE needs dst"))?, src }
        }
        "BALLOT" => {
            let src = c.operand()?;
            Inst::Ballot { dst: dst.ok_or_else(|| c.err("BALLOT needs dst"))?, src }
        }
        "SHFL" => {
            let kind = match parts.get(1).copied().unwrap_or("") {
                "IDX" => ShflKind::Idx,
                "DOWN" => ShflKind::Down,
                "UP" => ShflKind::Up,
                "XOR" => ShflKind::Xor,
                other => return Err(c.err(format!("bad shfl `{other}`"))),
            };
            let ty = scalar_of(parts.get(2).copied().unwrap_or(""), c)?;
            let val = c.operand()?;
            c.expect(",")?;
            let lane = c.operand()?;
            Inst::Shfl { kind, ty, dst: dst.ok_or_else(|| c.err("SHFL needs dst"))?, val, lane }
        }
        "RNG" => {
            let state = c.reg()?;
            Inst::Rng { dst: dst.ok_or_else(|| c.err("RNG needs dst"))?, state }
        }
        "TRAP" => Inst::Trap { code: c.int()? as u32 },
        other => return Err(c.err(format!("unknown mnemonic `{other}`"))),
    };
    c.expect(";")?;
    Ok(inst)
}

/// Parse a statement block until the closing `}` (not consumed).
fn parse_block(c: &mut Cursor) -> Result<Vec<Stmt>> {
    let mut stmts = Vec::new();
    loop {
        match c.peek() {
            None => return Err(c.err("unexpected EOF in block")),
            Some('}') => return Ok(stmts),
            _ => {}
        }
        if c.eat("@PRED") {
            let cond = c.reg()?;
            c.expect("{")?;
            let then_b = parse_block(c)?;
            c.expect("}")?;
            let else_b = if c.eat("ELSE") {
                c.expect("{")?;
                let e = parse_block(c)?;
                c.expect("}")?;
                e
            } else {
                Vec::new()
            };
            stmts.push(Stmt::If { cond, then_b, else_b });
            continue;
        }
        if c.eat("LOOP") {
            c.expect("{")?;
            // condition block ends with `TEST %r;`
            let mut cond = Vec::new();
            let cond_reg;
            loop {
                if c.eat("TEST") {
                    cond_reg = c.reg()?;
                    c.expect(";")?;
                    break;
                }
                cond.append(&mut parse_one(c)?);
            }
            c.expect("}")?;
            c.expect("BODY")?;
            c.expect("{")?;
            let body = parse_block(c)?;
            c.expect("}")?;
            stmts.push(Stmt::While { cond, cond_reg, body });
            continue;
        }
        stmts.append(&mut parse_one(c)?);
    }
}

/// Parse a single simple statement (instruction / BREAK / CONTINUE / RET,
/// or a nested structured statement).
fn parse_one(c: &mut Cursor) -> Result<Vec<Stmt>> {
    if c.eat("BREAK;") || (c.eat("BREAK") && c.eat(";")) {
        return Ok(vec![Stmt::Break]);
    }
    if c.eat("CONTINUE;") || (c.eat("CONTINUE") && c.eat(";")) {
        return Ok(vec![Stmt::Continue]);
    }
    if c.eat("RET;") || (c.eat("RET") && c.eat(";")) {
        return Ok(vec![Stmt::Return]);
    }
    if c.eat("@PRED") {
        let cond = c.reg()?;
        c.expect("{")?;
        let then_b = parse_block(c)?;
        c.expect("}")?;
        let else_b = if c.eat("ELSE") {
            c.expect("{")?;
            let e = parse_block(c)?;
            c.expect("}")?;
            e
        } else {
            Vec::new()
        };
        return Ok(vec![Stmt::If { cond, then_b, else_b }]);
    }
    // `%rN = MNEMONIC ...;` or `MNEMONIC ...;`
    let dst = if c.peek() == Some('%') {
        let r = c.reg()?;
        c.expect("=")?;
        Some(r)
    } else {
        None
    };
    Ok(vec![Stmt::I(parse_inst(c, dst)?)])
}

/// Parse one kernel starting at `.kernel`.
fn parse_kernel(c: &mut Cursor) -> Result<Kernel> {
    c.expect(".kernel")?;
    let name = c.word()?;
    let mut k = Kernel::new(name);
    c.expect("(")?;
    if !c.eat(")") {
        loop {
            let r = c.reg()?;
            if r.0 as usize != k.params.len() {
                return Err(c.err("parameter registers must be dense from %r0"));
            }
            c.expect(":")?;
            let ty = c.ty()?;
            let pname = c.word()?;
            k.new_reg(ty);
            k.params.push(Param { name: pname, ty });
            if c.eat(")") {
                break;
            }
            c.expect(",")?;
        }
    }
    c.expect(".shared")?;
    k.shared_bytes = c.int()? as u64;
    c.expect("{")?;
    // register declarations
    while c.eat(".reg") {
        loop {
            c.skip_ws();
            if !c.src[c.pos..].starts_with("%r") {
                break;
            }
            // An instruction line also starts with %rN; only consume the
            // register if a `:` (declaration) follows rather than `=`.
            let save = c.pos;
            let r = c.reg()?;
            if !c.eat(":") {
                c.pos = save;
                break;
            }
            let ty = c.ty()?;
            if r.0 as usize != k.reg_types.len() {
                return Err(c.err(format!(
                    "register declarations must be dense: got %r{}, expected %r{}",
                    r.0,
                    k.reg_types.len()
                )));
            }
            k.new_reg(ty);
        }
    }
    k.body = parse_block(c)?;
    c.expect("}")?;
    // Re-derive migration metadata from the (already-numbered) barriers.
    super::passes::segmenter::run(&mut k);
    super::passes::liveness::run(&mut k);
    Ok(k)
}

/// Parse a whole module from text.
pub fn parse_module(src: &str) -> Result<Module> {
    let mut c = Cursor::new(src);
    c.expect(".module")?;
    c.expect("\"")?;
    let start = c.pos;
    while c.pos < c.src.len() && c.src.as_bytes()[c.pos] != b'"' {
        c.pos += 1;
    }
    let name = c.src[start..c.pos].to_string();
    c.expect("\"")?;
    let mut m = Module::new(name);
    while !c.eof() {
        m.kernels.push(parse_kernel(&mut c)?);
    }
    Ok(m)
}

/// Parse a single kernel from text (no `.module` header).
pub fn parse_kernel_text(src: &str) -> Result<Kernel> {
    let mut c = Cursor::new(src);
    parse_kernel(&mut c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetir::builder::KernelBuilder;
    use crate::hetir::printer;

    fn vadd_kernel() -> Kernel {
        let mut b = KernelBuilder::new("vadd");
        let a = b.param("A", Type::PTR_GLOBAL);
        let bb = b.param("B", Type::PTR_GLOBAL);
        let cc = b.param("C", Type::PTR_GLOBAL);
        let n = b.param("N", Type::U32);
        let i = b.special(SpecialReg::GlobalId(Dim::X));
        let p = b.cmp(CmpOp::Lt, Scalar::U32, i.into(), n.into());
        b.if_(p, |b| {
            let x = b.ld(AddrSpace::Global, Scalar::F32, Address::indexed(a, i, 4));
            let y = b.ld(AddrSpace::Global, Scalar::F32, Address::indexed(bb, i, 4));
            let s = b.bin(BinOp::Add, Scalar::F32, x.into(), y.into());
            b.st(AddrSpace::Global, Scalar::F32, Address::indexed(cc, i, 4), s.into());
        });
        b.ret();
        b.finish()
    }

    #[test]
    fn roundtrip_vadd() {
        let k = vadd_kernel();
        let text = printer::print_kernel(&k);
        let k2 = parse_kernel_text(&text).unwrap();
        assert_eq!(k, k2, "parse(print(k)) != k\ntext:\n{text}");
    }

    #[test]
    fn roundtrip_module_with_loops_and_atomics() {
        let mut m = Module::new("mixed");
        m.add_kernel(vadd_kernel());
        let mut b = KernelBuilder::new("looped");
        let out = b.param("O", Type::PTR_GLOBAL);
        let n = b.param("N", Type::U32);
        let acc = b.mov(Type::F32, Operand::Imm(Value::f32(0.5)));
        b.for_u32(Operand::Imm(Value::u32(0)), n.into(), 1, |b, _i| {
            b.bin_into(acc, BinOp::Add, Scalar::F32, acc.into(), Operand::Imm(Value::f32(1.0)));
            b.bar();
        });
        let _old = b.atom(
            AtomOp::Add,
            AddrSpace::Global,
            Scalar::U32,
            Address::base(out),
            Operand::Imm(Value::u32(1)),
        );
        let _old2 = b.atom(
            AtomOp::Xor,
            AddrSpace::Global,
            Scalar::U32,
            Address::base(out).with_disp(4),
            Operand::Imm(Value::u32(0xA5)),
        );
        b.st(AddrSpace::Global, Scalar::F32, Address::base(out).with_disp(8), acc.into());
        m.add_kernel(b.finish());

        let text = printer::print_module(&m);
        let m2 = parse_module(&text).unwrap();
        assert_eq!(m, m2, "module roundtrip failed:\n{text}");
    }

    #[test]
    fn roundtrip_preserves_float_bits() {
        let mut b = KernelBuilder::new("f");
        let out = b.param("O", Type::PTR_GLOBAL);
        for bits in [0x7FC0_0001u32, 0x8000_0000, 0xFF80_0000] {
            // NaN payload, -0.0, -inf
            let v = Value { bits: bits as u64, ty: Type::F32 };
            b.st(AddrSpace::Global, Scalar::F32, Address::base(out), Operand::Imm(v));
        }
        let k = b.finish();
        let text = printer::print_kernel(&k);
        let k2 = parse_kernel_text(&text).unwrap();
        assert_eq!(k, k2);
    }

    #[test]
    fn comments_and_whitespace_tolerated() {
        let src = r#"
.kernel k(%r0:u32 n) .shared 16 {
  .reg %r1:u32 // a comment
  // full line comment
  %r1 = ADD.U32   %r0 ,  1:u32 ;
  RET;
}
"#;
        let k = parse_kernel_text(src).unwrap();
        assert_eq!(k.shared_bytes, 16);
        assert_eq!(k.inst_count(), 1);
    }

    #[test]
    fn error_reports_line() {
        let src = ".kernel k(%r0:u32 n) .shared 0 {\n  %r1 = BOGUS.U32 %r0;\n}";
        let err = parse_kernel_text(src).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("line 2"), "{msg}");
    }

    #[test]
    fn rejects_sparse_registers() {
        let src = ".kernel k(%r0:u32 n) .shared 0 {\n  .reg %r5:u32\n  RET;\n}";
        assert!(parse_kernel_text(src).is_err());
    }

    #[test]
    fn cas_requires_two_values() {
        let src = ".kernel k(%r0:ptr<global> p) .shared 0 {\n  .reg %r1:u32\n  %r1 = ATOM.CAS.GLOBAL.U32 [%r0], 1:u32;\n}";
        assert!(parse_kernel_text(src).is_err());
    }
}
