//! Convenience builder for constructing hetIR kernels programmatically.
//!
//! Used by the CUDA-subset frontend's codegen and by hand-written kernels in
//! tests/benches. The builder keeps a stack of open statement blocks so
//! structured control flow nests via closures:
//!
//! ```no_run
//! use hetgpu::hetir::builder::KernelBuilder;
//! use hetgpu::hetir::types::{AddrSpace, Type, Scalar};
//! use hetgpu::hetir::instr::{Address, CmpOp, Dim, SpecialReg};
//!
//! let mut b = KernelBuilder::new("vadd");
//! let a = b.param("A", Type::PTR_GLOBAL);
//! let x = b.param("X", Type::PTR_GLOBAL);
//! let n = b.param("N", Type::U32);
//! let i = b.special(SpecialReg::GlobalId(Dim::X));
//! let p = b.cmp(CmpOp::Lt, Scalar::U32, i.into(), n.into());
//! b.if_(p, |b| {
//!     let v = b.ld(AddrSpace::Global, Scalar::F32, Address::indexed(a, i, 4));
//!     b.st(AddrSpace::Global, Scalar::F32, Address::indexed(x, i, 4), v.into());
//! });
//! let kernel = b.finish();
//! assert_eq!(kernel.name, "vadd");
//! ```

use super::instr::*;
use super::module::{Kernel, Param, Stmt};
use super::passes;
use super::types::{AddrSpace, Scalar, Type, Value};

/// Re-export so builder call sites read naturally.
pub type AddrSpaceArg = AddrSpace;

/// Builder for a single kernel.
pub struct KernelBuilder {
    kernel: Kernel,
    /// Stack of open statement blocks; `stack[0]` is the kernel body.
    stack: Vec<Vec<Stmt>>,
}

impl KernelBuilder {
    pub fn new(name: impl Into<String>) -> KernelBuilder {
        KernelBuilder { kernel: Kernel::new(name), stack: vec![Vec::new()] }
    }

    /// Declare a kernel parameter. Parameters occupy the first registers.
    pub fn param(&mut self, name: impl Into<String>, ty: Type) -> Reg {
        assert!(
            self.kernel.reg_types.len() == self.kernel.params.len(),
            "params must be declared before any other registers"
        );
        let r = self.kernel.new_reg(ty);
        self.kernel.params.push(Param { name: name.into(), ty });
        r
    }

    /// Reserve `bytes` of block-shared memory, returning a pointer register
    /// pre-set to the current offset (so multiple `__shared__` arrays pack).
    pub fn shared_alloc(&mut self, bytes: u64) -> Reg {
        let off = self.kernel.shared_bytes;
        self.kernel.shared_bytes += (bytes + 15) & !15; // 16-byte align
        let r = self.kernel.new_reg(Type::PTR_SHARED);
        self.push(Inst::Mov { dst: r, src: Operand::Imm(Value::ptr(off, AddrSpace::Shared)) });
        r
    }

    /// Allocate a fresh register.
    pub fn reg(&mut self, ty: Type) -> Reg {
        self.kernel.new_reg(ty)
    }

    /// Append a raw instruction.
    pub fn push(&mut self, i: Inst) {
        self.stack.last_mut().unwrap().push(Stmt::I(i));
    }

    // ---- instruction conveniences (allocate dst, append, return dst) ----

    pub fn special(&mut self, kind: SpecialReg) -> Reg {
        let dst = self.reg(Type::U32);
        self.push(Inst::Special { dst, kind });
        dst
    }

    pub fn mov(&mut self, ty: Type, src: Operand) -> Reg {
        let dst = self.reg(ty);
        self.push(Inst::Mov { dst, src });
        dst
    }

    pub fn imm_u32(&mut self, v: u32) -> Operand {
        Operand::Imm(Value::u32(v))
    }

    pub fn imm_f32(&mut self, v: f32) -> Operand {
        Operand::Imm(Value::f32(v))
    }

    pub fn bin(&mut self, op: BinOp, ty: Scalar, a: Operand, b: Operand) -> Reg {
        let dst = self.reg(Type::Scalar(ty));
        self.push(Inst::Bin { op, ty, dst, a, b });
        dst
    }

    /// Binary op writing into an existing register (for loop-carried vars).
    pub fn bin_into(&mut self, dst: Reg, op: BinOp, ty: Scalar, a: Operand, b: Operand) {
        self.push(Inst::Bin { op, ty, dst, a, b });
    }

    pub fn un(&mut self, op: UnOp, ty: Scalar, a: Operand) -> Reg {
        let dst = self.reg(Type::Scalar(ty));
        self.push(Inst::Un { op, ty, dst, a });
        dst
    }

    pub fn fma(&mut self, ty: Scalar, a: Operand, b: Operand, c: Operand) -> Reg {
        let dst = self.reg(Type::Scalar(ty));
        self.push(Inst::Fma { ty, dst, a, b, c });
        dst
    }

    pub fn cmp(&mut self, op: CmpOp, ty: Scalar, a: Operand, b: Operand) -> Reg {
        let dst = self.reg(Type::PRED);
        self.push(Inst::Cmp { op, ty, dst, a, b });
        dst
    }

    pub fn sel(&mut self, ty: Type, cond: Operand, a: Operand, b: Operand) -> Reg {
        let dst = self.reg(ty);
        self.push(Inst::Sel { dst, cond, a, b });
        dst
    }

    pub fn cvt(&mut self, from: Scalar, to: Scalar, src: Operand) -> Reg {
        let dst = self.reg(Type::Scalar(to));
        self.push(Inst::Cvt { from, to, dst, src });
        dst
    }

    /// Pointer arithmetic producing a new pointer register of the same
    /// address space as `addr.base`.
    pub fn ptr_add(&mut self, space: AddrSpace, addr: Address) -> Reg {
        let dst = self.reg(Type::Ptr(space));
        self.push(Inst::PtrAdd { dst, addr });
        dst
    }

    pub fn ld(&mut self, space: AddrSpace, ty: Scalar, addr: Address) -> Reg {
        let dst = self.reg(Type::Scalar(ty));
        self.push(Inst::Ld { space, ty, dst, addr });
        dst
    }

    pub fn st(&mut self, space: AddrSpace, ty: Scalar, addr: Address, val: Operand) {
        self.push(Inst::St { space, ty, addr, val });
    }

    pub fn atom(
        &mut self,
        op: AtomOp,
        space: AddrSpace,
        ty: Scalar,
        addr: Address,
        val: Operand,
    ) -> Reg {
        let dst = self.reg(Type::Scalar(ty));
        self.push(Inst::Atom { op, space, ty, dst: Some(dst), addr, val, val2: None });
        dst
    }

    /// Barrier; the id is provisional (the segmenter pass renumbers).
    pub fn bar(&mut self) {
        self.push(Inst::Bar { id: u32::MAX });
    }

    pub fn fence(&mut self, scope: FenceScope) {
        self.push(Inst::Fence { scope });
    }

    pub fn vote(&mut self, kind: VoteKind, src: Operand) -> Reg {
        let dst = self.reg(Type::PRED);
        self.push(Inst::Vote { kind, dst, src });
        dst
    }

    pub fn ballot(&mut self, src: Operand) -> Reg {
        let dst = self.reg(Type::U32);
        self.push(Inst::Ballot { dst, src });
        dst
    }

    pub fn shfl(&mut self, kind: ShflKind, ty: Scalar, val: Operand, lane: Operand) -> Reg {
        let dst = self.reg(Type::Scalar(ty));
        self.push(Inst::Shfl { kind, ty, dst, val, lane });
        dst
    }

    pub fn rng(&mut self, state: Reg) -> Reg {
        let dst = self.reg(Type::U32);
        self.push(Inst::Rng { dst, state });
        dst
    }

    pub fn ret(&mut self) {
        self.stack.last_mut().unwrap().push(Stmt::Return);
    }

    pub fn brk(&mut self) {
        self.stack.last_mut().unwrap().push(Stmt::Break);
    }

    pub fn cont(&mut self) {
        self.stack.last_mut().unwrap().push(Stmt::Continue);
    }

    // ---- low-level block API (used by frontend codegen, which cannot
    // thread its own state through the closure-style API below) ----

    /// Open a fresh statement block; closed by [`Self::pop_block`].
    pub fn push_block(&mut self) {
        self.stack.push(Vec::new());
    }

    /// Close the innermost open block and return its statements.
    pub fn pop_block(&mut self) -> Vec<Stmt> {
        assert!(self.stack.len() > 1, "pop_block on kernel body");
        self.stack.pop().unwrap()
    }

    /// Append an arbitrary structured statement.
    pub fn push_stmt(&mut self, s: Stmt) {
        self.stack.last_mut().unwrap().push(s);
    }

    // ---- structured control flow ----

    /// `if (cond) { then }`.
    pub fn if_(&mut self, cond: Reg, then_f: impl FnOnce(&mut Self)) {
        self.stack.push(Vec::new());
        then_f(self);
        let then_b = self.stack.pop().unwrap();
        self.stack.last_mut().unwrap().push(Stmt::If { cond, then_b, else_b: Vec::new() });
    }

    /// `if (cond) { then } else { else }`.
    pub fn if_else(
        &mut self,
        cond: Reg,
        then_f: impl FnOnce(&mut Self),
        else_f: impl FnOnce(&mut Self),
    ) {
        self.stack.push(Vec::new());
        then_f(self);
        let then_b = self.stack.pop().unwrap();
        self.stack.push(Vec::new());
        else_f(self);
        let else_b = self.stack.pop().unwrap();
        self.stack.last_mut().unwrap().push(Stmt::If { cond, then_b, else_b });
    }

    /// Structured while loop: `cond_f` emits the condition computation and
    /// returns the predicate register; `body_f` emits the body.
    pub fn while_(
        &mut self,
        cond_f: impl FnOnce(&mut Self) -> Reg,
        body_f: impl FnOnce(&mut Self),
    ) {
        self.stack.push(Vec::new());
        let cond_reg = cond_f(self);
        let cond = self.stack.pop().unwrap();
        self.stack.push(Vec::new());
        body_f(self);
        let body = self.stack.pop().unwrap();
        self.stack.last_mut().unwrap().push(Stmt::While { cond, cond_reg, body });
    }

    /// Counted loop helper: `for (i = start; i < end; i += step)` over u32,
    /// with `i` exposed to the body. Returns the induction register.
    pub fn for_u32(
        &mut self,
        start: Operand,
        end: Operand,
        step: u32,
        body_f: impl FnOnce(&mut Self, Reg),
    ) -> Reg {
        let i = self.mov(Type::U32, start);
        self.while_(
            |b| b.cmp(CmpOp::Lt, Scalar::U32, i.into(), end),
            |b| {
                body_f(b, i);
                b.bin_into(i, BinOp::Add, Scalar::U32, i.into(), Operand::Imm(Value::u32(step)));
            },
        );
        i
    }

    /// Finish the kernel: closes the body, assigns barrier ids (segmenter)
    /// and computes suspension-point liveness.
    pub fn finish(mut self) -> Kernel {
        assert_eq!(self.stack.len(), 1, "unclosed control-flow block");
        self.kernel.body = self.stack.pop().unwrap();
        passes::segmenter::run(&mut self.kernel);
        passes::liveness::run(&mut self.kernel);
        self.kernel
    }

    /// Finish without running passes (for parser/pass unit tests).
    pub fn finish_raw(mut self) -> Kernel {
        assert_eq!(self.stack.len(), 1, "unclosed control-flow block");
        self.kernel.body = self.stack.pop().unwrap();
        self.kernel
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_vadd_shape() {
        let mut b = KernelBuilder::new("vadd");
        let a = b.param("A", Type::PTR_GLOBAL);
        let c = b.param("C", Type::PTR_GLOBAL);
        let n = b.param("N", Type::U32);
        let i = b.special(SpecialReg::GlobalId(Dim::X));
        let p = b.cmp(CmpOp::Lt, Scalar::U32, i.into(), n.into());
        b.if_(p, |b| {
            let v = b.ld(AddrSpace::Global, Scalar::F32, Address::indexed(a, i, 4));
            b.st(AddrSpace::Global, Scalar::F32, Address::indexed(c, i, 4), v.into());
        });
        let k = b.finish();
        assert_eq!(k.params.len(), 3);
        assert_eq!(k.inst_count(), 4);
        assert_eq!(k.num_barriers, 0);
    }

    #[test]
    fn nested_loops_and_barriers_get_ids() {
        let mut b = KernelBuilder::new("k");
        let n = b.param("N", Type::U32);
        b.for_u32(Operand::Imm(Value::u32(0)), n.into(), 1, |b, _i| {
            b.bar();
        });
        b.bar();
        let k = b.finish();
        assert_eq!(k.num_barriers, 2);
        // barrier ids are distinct and dense
        let mut ids = vec![];
        k.visit_insts(|i| {
            if let Inst::Bar { id } = i {
                ids.push(*id);
            }
        });
        ids.sort();
        assert_eq!(ids, vec![0, 1]);
    }

    #[test]
    #[should_panic(expected = "params must be declared before")]
    fn params_after_regs_panics() {
        let mut b = KernelBuilder::new("k");
        b.reg(Type::F32);
        b.param("late", Type::U32);
    }

    #[test]
    fn shared_alloc_packs_aligned() {
        let mut b = KernelBuilder::new("k");
        let s0 = b.shared_alloc(20);
        let s1 = b.shared_alloc(4);
        let k = b.finish();
        assert_eq!(k.shared_bytes, 32 + 16);
        assert_eq!(k.reg_ty(s0), Type::PTR_SHARED);
        assert_eq!(k.reg_ty(s1), Type::PTR_SHARED);
    }
}
