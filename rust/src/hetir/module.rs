//! hetIR module and kernel structures.
//!
//! A [`Module`] is the unit the compiler emits and the runtime loads — the
//! paper's "single hetIR binary containing N kernels" (§6.1). Each
//! [`Kernel`] carries:
//!
//! * a typed parameter list,
//! * a static shared-memory size,
//! * a typed virtual register file declaration,
//! * a *structured* body ([`Stmt`] tree), and
//! * migration metadata: barrier/segment ids and (after the liveness pass)
//!   the live-register set at every suspension point.

use super::instr::{Inst, Reg};
use super::types::Type;
use std::collections::HashMap;

/// A kernel parameter.
#[derive(Debug, Clone, PartialEq)]
pub struct Param {
    pub name: String,
    pub ty: Type,
}

/// Structured control flow statement.
///
/// hetIR deliberately has no arbitrary gotos: every divergent region has a
/// single reconvergence point given by the structure, which (a) satisfies
/// SPIR-V's structured-merge requirement directly (paper §5.1 "SPIR-V
/// demands structured merges, which our compiler inherently had by
/// structured @PRED blocks"), and (b) makes divergence mapping onto both
/// hardware mask stacks and software vector masks mechanical.
#[derive(Debug, Clone, PartialEq)]
pub enum Stmt {
    /// A straight-line instruction.
    I(Inst),
    /// Predicated region with implicit reconvergence after it.
    If { cond: Reg, then_b: Vec<Stmt>, else_b: Vec<Stmt> },
    /// Structured loop: execute `cond` statements, test `cond_reg`; if
    /// true run `body` and repeat, else exit. Reconvergence at loop exit.
    While { cond: Vec<Stmt>, cond_reg: Reg, body: Vec<Stmt> },
    /// Exit the innermost enclosing `While` (may be divergent).
    Break,
    /// Skip to the condition of the innermost enclosing `While`.
    Continue,
    /// Terminate this thread (reconverges only at kernel end).
    Return,
}

impl Stmt {
    /// Visit all instructions in this statement tree (immutable).
    pub fn visit_insts<'a>(&'a self, f: &mut impl FnMut(&'a Inst)) {
        match self {
            Stmt::I(i) => f(i),
            Stmt::If { then_b, else_b, .. } => {
                for s in then_b {
                    s.visit_insts(f);
                }
                for s in else_b {
                    s.visit_insts(f);
                }
            }
            Stmt::While { cond, body, .. } => {
                for s in cond {
                    s.visit_insts(f);
                }
                for s in body {
                    s.visit_insts(f);
                }
            }
            Stmt::Break | Stmt::Continue | Stmt::Return => {}
        }
    }

    /// Visit all instructions in this statement tree (mutable).
    pub fn visit_insts_mut(&mut self, f: &mut impl FnMut(&mut Inst)) {
        match self {
            Stmt::I(i) => f(i),
            Stmt::If { then_b, else_b, .. } => {
                for s in then_b {
                    s.visit_insts_mut(f);
                }
                for s in else_b {
                    s.visit_insts_mut(f);
                }
            }
            Stmt::While { cond, body, .. } => {
                for s in cond {
                    s.visit_insts_mut(f);
                }
                for s in body {
                    s.visit_insts_mut(f);
                }
            }
            Stmt::Break | Stmt::Continue | Stmt::Return => {}
        }
    }
}

/// Per-suspension-point migration metadata, filled by the liveness pass.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SuspensionPoint {
    /// The barrier id (== segment boundary id) this point corresponds to.
    pub barrier_id: u32,
    /// Virtual registers live across this barrier, in ascending order.
    /// Only these are captured into a snapshot (paper §8: "only saving
    /// live registers (not entire register files)").
    pub live_regs: Vec<Reg>,
}

/// A hetIR kernel: the unit of launch.
#[derive(Debug, Clone, PartialEq)]
pub struct Kernel {
    pub name: String,
    pub params: Vec<Param>,
    /// Static shared-memory ("scratchpad") requirement in bytes.
    pub shared_bytes: u64,
    /// Types of the virtual registers; `Reg(i)` has type `reg_types[i]`.
    /// Parameters are pre-loaded into registers `0..params.len()`.
    pub reg_types: Vec<Type>,
    /// Structured body.
    pub body: Vec<Stmt>,
    /// Number of barriers (assigned by the segmenter; barrier ids are
    /// `0..num_barriers`). Segment ids are `0..=num_barriers`: segment 0 is
    /// kernel entry, segment `b+1` starts just after barrier `b`.
    pub num_barriers: u32,
    /// Suspension-point metadata (index = barrier id), filled by liveness.
    pub suspension_points: Vec<SuspensionPoint>,
}

impl Kernel {
    pub fn new(name: impl Into<String>) -> Kernel {
        Kernel {
            name: name.into(),
            params: Vec::new(),
            shared_bytes: 0,
            reg_types: Vec::new(),
            body: Vec::new(),
            num_barriers: 0,
            suspension_points: Vec::new(),
        }
    }

    /// Allocate a fresh virtual register of type `ty`.
    pub fn new_reg(&mut self, ty: Type) -> Reg {
        let r = Reg(self.reg_types.len() as u32);
        self.reg_types.push(ty);
        r
    }

    /// The type of register `r` (panics on out-of-range: that is an IR bug
    /// the verifier reports with context before execution ever gets here).
    pub fn reg_ty(&self, r: Reg) -> Type {
        self.reg_types[r.0 as usize]
    }

    /// Visit every instruction in the kernel body.
    pub fn visit_insts<'a>(&'a self, mut f: impl FnMut(&'a Inst)) {
        for s in &self.body {
            s.visit_insts(&mut f);
        }
    }

    /// Visit every instruction in the kernel body, mutably.
    pub fn visit_insts_mut(&mut self, mut f: impl FnMut(&mut Inst)) {
        for s in &mut self.body {
            s.visit_insts_mut(&mut f);
        }
    }

    /// Count instructions (diagnostics / cost estimates).
    pub fn inst_count(&self) -> usize {
        let mut n = 0;
        self.visit_insts(|_| n += 1);
        n
    }

    /// The suspension-point metadata for barrier `id`, if liveness ran.
    pub fn suspension_point(&self, id: u32) -> Option<&SuspensionPoint> {
        self.suspension_points.iter().find(|p| p.barrier_id == id)
    }
}

/// A hetIR module: a named collection of kernels ("one binary").
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Module {
    pub name: String,
    pub kernels: Vec<Kernel>,
    /// Source mapping / provenance notes (DWARF-like, paper §4.1), purely
    /// informational.
    pub annotations: HashMap<String, String>,
}

impl Module {
    pub fn new(name: impl Into<String>) -> Module {
        Module { name: name.into(), kernels: Vec::new(), annotations: HashMap::new() }
    }

    /// Add a kernel, returning its index.
    pub fn add_kernel(&mut self, k: Kernel) -> usize {
        self.kernels.push(k);
        self.kernels.len() - 1
    }

    /// Find a kernel by name.
    pub fn kernel(&self, name: &str) -> Option<&Kernel> {
        self.kernels.iter().find(|k| k.name == name)
    }

    /// Find a kernel by name, mutably.
    pub fn kernel_mut(&mut self, name: &str) -> Option<&mut Kernel> {
        self.kernels.iter_mut().find(|k| k.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetir::instr::{BinOp, Operand};
    use crate::hetir::types::Scalar;

    fn mk_add(dst: u32, a: u32, b: u32) -> Stmt {
        Stmt::I(Inst::Bin {
            op: BinOp::Add,
            ty: Scalar::F32,
            dst: Reg(dst),
            a: Operand::Reg(Reg(a)),
            b: Operand::Reg(Reg(b)),
        })
    }

    #[test]
    fn reg_allocation_and_typing() {
        let mut k = Kernel::new("k");
        let r0 = k.new_reg(Type::F32);
        let r1 = k.new_reg(Type::PTR_GLOBAL);
        assert_eq!(r0, Reg(0));
        assert_eq!(r1, Reg(1));
        assert_eq!(k.reg_ty(r0), Type::F32);
        assert_eq!(k.reg_ty(r1), Type::PTR_GLOBAL);
    }

    #[test]
    fn visit_counts_nested() {
        let mut k = Kernel::new("k");
        for _ in 0..4 {
            k.new_reg(Type::F32);
        }
        k.body = vec![
            mk_add(2, 0, 1),
            Stmt::If {
                cond: Reg(3),
                then_b: vec![mk_add(2, 2, 0)],
                else_b: vec![mk_add(2, 2, 1), mk_add(2, 2, 2)],
            },
            Stmt::While { cond: vec![mk_add(2, 2, 2)], cond_reg: Reg(3), body: vec![mk_add(2, 0, 0)] },
        ];
        assert_eq!(k.inst_count(), 6);
    }

    #[test]
    fn module_lookup() {
        let mut m = Module::new("test");
        m.add_kernel(Kernel::new("a"));
        m.add_kernel(Kernel::new("b"));
        assert!(m.kernel("a").is_some());
        assert!(m.kernel("c").is_none());
        m.kernel_mut("b").unwrap().shared_bytes = 128;
        assert_eq!(m.kernel("b").unwrap().shared_bytes, 128);
    }
}
