//! hetIR structural and type verifier.
//!
//! Runs before any backend translation (the runtime refuses to JIT an
//! unverified module). Checks:
//!
//! * register indices in range; operand/destination types consistent with
//!   each opcode's typing rules;
//! * `If`/`While` condition registers are predicates;
//! * address bases are pointer-typed into the address space the memory op
//!   names;
//! * `Break`/`Continue` appear only inside loops;
//! * barriers do not sit under divergent control flow (via the uniformity
//!   analysis — the cross-platform UB the paper's design must avoid);
//! * barrier ids are dense and match `num_barriers` (segmenter ran).

use super::analyze::{SegKind, StmtPath};
use super::instr::*;
use super::module::{Kernel, Module, Stmt};
use super::passes::uniformity;
use super::types::{AddrSpace, Scalar, Type};
use crate::error::{HetError, Result};

struct V<'k> {
    k: &'k Kernel,
    loop_depth: usize,
    barrier_ids: Vec<u32>,
    /// Statement path of the statement currently being checked, rendered
    /// into every error — the same location language the static
    /// analyzer's diagnostics use.
    path: Vec<(SegKind, u32)>,
}

impl<'k> V<'k> {
    fn err(&self, msg: impl Into<String>) -> HetError {
        HetError::Verify {
            func: self.k.name.clone(),
            stmt: StmtPath(self.path.clone()).to_string(),
            msg: msg.into(),
        }
    }

    fn reg_ty(&self, r: Reg) -> Result<Type> {
        self.k
            .reg_types
            .get(r.0 as usize)
            .copied()
            .ok_or_else(|| self.err(format!("register {r} out of range")))
    }

    fn check_operand(&self, o: &Operand, want: Type, what: &str) -> Result<()> {
        let got = match o {
            Operand::Reg(r) => self.reg_ty(*r)?,
            Operand::Imm(v) => v.ty,
        };
        if got != want {
            return Err(self.err(format!("{what}: expected {want}, got {got}")));
        }
        Ok(())
    }

    fn check_dst(&self, r: Reg, want: Type, what: &str) -> Result<()> {
        let got = self.reg_ty(r)?;
        if got != want {
            return Err(self.err(format!("{what}: dst {r} is {got}, expected {want}")));
        }
        Ok(())
    }

    fn check_addr(&self, a: &Address, space: AddrSpace, what: &str) -> Result<()> {
        match self.reg_ty(a.base)? {
            Type::Ptr(s) if s == space => {}
            other => {
                return Err(self.err(format!(
                    "{what}: base {} has type {other}, expected ptr<{space}>",
                    a.base
                )))
            }
        }
        if let Some(i) = a.index {
            let t = self.reg_ty(i)?;
            if !matches!(t, Type::Scalar(s) if s.is_int()) {
                return Err(self.err(format!("{what}: index {i} must be integer, got {t}")));
            }
            if a.scale == 0 {
                return Err(self.err(format!("{what}: zero scale with index")));
            }
        }
        Ok(())
    }

    fn check_inst(&mut self, i: &Inst) -> Result<()> {
        match i {
            Inst::Special { dst, .. } => self.check_dst(*dst, Type::U32, "special")?,
            Inst::Mov { dst, src } => {
                let want = self.reg_ty(*dst)?;
                self.check_operand(src, want, "MOV src")?;
            }
            Inst::Bin { op, ty, dst, a, b } => {
                if *ty == Scalar::Pred
                    && !matches!(op, BinOp::And | BinOp::Or | BinOp::Xor)
                {
                    return Err(self.err(format!("{op:?} not defined on predicates")));
                }
                if ty.is_float()
                    && matches!(op, BinOp::And | BinOp::Or | BinOp::Xor | BinOp::Shl | BinOp::Shr)
                {
                    return Err(self.err(format!("{op:?} not defined on floats")));
                }
                self.check_dst(*dst, Type::Scalar(*ty), "bin dst")?;
                self.check_operand(a, Type::Scalar(*ty), "bin lhs")?;
                self.check_operand(b, Type::Scalar(*ty), "bin rhs")?;
            }
            Inst::Un { op, ty, dst, a } => {
                let dst_ty = if *op == UnOp::Popc { Type::U32 } else { Type::Scalar(*ty) };
                self.check_dst(*dst, dst_ty, "un dst")?;
                self.check_operand(a, Type::Scalar(*ty), "un src")?;
            }
            Inst::Fma { ty, dst, a, b, c } => {
                if !ty.is_float() {
                    return Err(self.err("FMA is float-only"));
                }
                self.check_dst(*dst, Type::Scalar(*ty), "fma dst")?;
                for (o, w) in [(a, "fma a"), (b, "fma b"), (c, "fma c")] {
                    self.check_operand(o, Type::Scalar(*ty), w)?;
                }
            }
            Inst::Cmp { ty, dst, a, b, .. } => {
                self.check_dst(*dst, Type::PRED, "setp dst")?;
                self.check_operand(a, Type::Scalar(*ty), "setp lhs")?;
                self.check_operand(b, Type::Scalar(*ty), "setp rhs")?;
            }
            Inst::Sel { dst, cond, a, b } => {
                self.check_operand(cond, Type::PRED, "sel cond")?;
                let want = self.reg_ty(*dst)?;
                self.check_operand(a, want, "sel a")?;
                self.check_operand(b, want, "sel b")?;
            }
            Inst::Cvt { from, to, dst, src } => {
                self.check_dst(*dst, Type::Scalar(*to), "cvt dst")?;
                self.check_operand(src, Type::Scalar(*from), "cvt src")?;
            }
            Inst::PtrAdd { dst, addr } => {
                let dst_ty = self.reg_ty(*dst)?;
                let base_ty = self.reg_ty(addr.base)?;
                if !dst_ty.is_ptr() || dst_ty != base_ty {
                    return Err(self.err(format!(
                        "PTRADD dst {dst}:{dst_ty} must match base {}:{base_ty}",
                        addr.base
                    )));
                }
                if let Some(i) = addr.index {
                    let t = self.reg_ty(i)?;
                    if !matches!(t, Type::Scalar(s) if s.is_int()) {
                        return Err(self.err("PTRADD index must be integer"));
                    }
                }
            }
            Inst::Ld { space, ty, dst, addr } => {
                self.check_addr(addr, *space, "LD")?;
                self.check_dst(*dst, Type::Scalar(*ty), "LD dst")?;
            }
            Inst::St { space, ty, addr, val } => {
                self.check_addr(addr, *space, "ST")?;
                self.check_operand(val, Type::Scalar(*ty), "ST val")?;
            }
            Inst::Atom { op, space, ty, dst, addr, val, val2 } => {
                if ty.is_float() && !matches!(op, AtomOp::Add | AtomOp::Exch) {
                    return Err(self.err(format!("ATOM.{op:?} not defined on floats")));
                }
                if *ty == Scalar::Pred {
                    return Err(self.err("atomics on predicates are invalid"));
                }
                self.check_addr(addr, *space, "ATOM")?;
                self.check_operand(val, Type::Scalar(*ty), "ATOM val")?;
                match (op, val2) {
                    (AtomOp::Cas, None) => return Err(self.err("ATOM.CAS needs val2")),
                    (AtomOp::Cas, Some(v2)) => {
                        self.check_operand(v2, Type::Scalar(*ty), "ATOM val2")?
                    }
                    (_, Some(_)) => return Err(self.err("val2 only valid for CAS")),
                    _ => {}
                }
                if let Some(d) = dst {
                    self.check_dst(*d, Type::Scalar(*ty), "ATOM dst")?;
                }
            }
            Inst::Bar { id } => self.barrier_ids.push(*id),
            Inst::Fence { .. } | Inst::Trap { .. } => {}
            Inst::Vote { dst, src, .. } => {
                self.check_dst(*dst, Type::PRED, "vote dst")?;
                self.check_operand(src, Type::PRED, "vote src")?;
            }
            Inst::Ballot { dst, src } => {
                self.check_dst(*dst, Type::U32, "ballot dst")?;
                self.check_operand(src, Type::PRED, "ballot src")?;
            }
            Inst::Shfl { ty, dst, val, lane, .. } => {
                self.check_dst(*dst, Type::Scalar(*ty), "shfl dst")?;
                self.check_operand(val, Type::Scalar(*ty), "shfl val")?;
                self.check_operand(lane, Type::U32, "shfl lane")?;
            }
            Inst::Rng { dst, state } => {
                self.check_dst(*dst, Type::U32, "rng dst")?;
                self.check_dst(*state, Type::U32, "rng state")?;
            }
        }
        Ok(())
    }

    fn check_block(&mut self, stmts: &[Stmt], seg: SegKind) -> Result<()> {
        for (idx, s) in stmts.iter().enumerate() {
            self.path.push((seg, idx as u32));
            match s {
                Stmt::I(i) => self.check_inst(i)?,
                Stmt::If { cond, then_b, else_b } => {
                    if self.reg_ty(*cond)? != Type::PRED {
                        return Err(self.err(format!("if condition {cond} must be pred")));
                    }
                    self.check_block(then_b, SegKind::Then)?;
                    self.check_block(else_b, SegKind::Else)?;
                }
                Stmt::While { cond, cond_reg, body } => {
                    if self.reg_ty(*cond_reg)? != Type::PRED {
                        return Err(self.err(format!("loop condition {cond_reg} must be pred")));
                    }
                    self.check_block(cond, SegKind::Cond)?;
                    self.loop_depth += 1;
                    self.check_block(body, SegKind::Body)?;
                    self.loop_depth -= 1;
                }
                Stmt::Break | Stmt::Continue => {
                    if self.loop_depth == 0 {
                        return Err(self.err("break/continue outside loop"));
                    }
                }
                Stmt::Return => {}
            }
            self.path.pop();
        }
        Ok(())
    }
}

/// Verify a single kernel.
pub fn verify_kernel(k: &Kernel) -> Result<()> {
    // Parameter registers must come first and match declared types.
    if k.params.len() > k.reg_types.len() {
        return Err(HetError::Verify {
            func: k.name.clone(),
            stmt: StmtPath::default().to_string(),
            msg: "fewer registers than parameters".into(),
        });
    }
    for (i, p) in k.params.iter().enumerate() {
        if k.reg_types[i] != p.ty {
            return Err(HetError::Verify {
                func: k.name.clone(),
                stmt: StmtPath::default().to_string(),
                msg: format!("param {} type mismatch: reg says {}, param says {}",
                    p.name, k.reg_types[i], p.ty),
            });
        }
    }
    let mut v = V { k, loop_depth: 0, barrier_ids: Vec::new(), path: Vec::new() };
    v.check_block(&k.body, SegKind::Body)?;
    // Barrier ids dense 0..num_barriers.
    let mut ids = v.barrier_ids.clone();
    ids.sort_unstable();
    let expect: Vec<u32> = (0..k.num_barriers).collect();
    if ids != expect {
        return Err(HetError::Verify {
            func: k.name.clone(),
            stmt: StmtPath::default().to_string(),
            msg: format!(
                "barrier ids {ids:?} are not dense 0..{} — run the segmenter",
                k.num_barriers
            ),
        });
    }
    // No barrier under divergence.
    if let Some(id) = uniformity::barrier_under_divergence(k) {
        return Err(HetError::Verify {
            func: k.name.clone(),
            stmt: StmtPath::default().to_string(),
            msg: format!("barrier {id} under divergent control flow"),
        });
    }
    Ok(())
}

/// Verify every kernel in a module.
pub fn verify_module(m: &Module) -> Result<()> {
    for k in &m.kernels {
        verify_kernel(k)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetir::builder::KernelBuilder;
    use crate::hetir::types::Value;

    #[test]
    fn accepts_wellformed() {
        let mut b = KernelBuilder::new("ok");
        let a = b.param("A", Type::PTR_GLOBAL);
        let i = b.special(SpecialReg::GlobalId(Dim::X));
        let v = b.ld(AddrSpace::Global, Scalar::F32, Address::indexed(a, i, 4));
        let w = b.bin(BinOp::Mul, Scalar::F32, v.into(), Operand::Imm(Value::f32(2.0)));
        b.st(AddrSpace::Global, Scalar::F32, Address::indexed(a, i, 4), w.into());
        assert!(verify_kernel(&b.finish()).is_ok());
    }

    #[test]
    fn rejects_type_mismatch() {
        let mut b = KernelBuilder::new("bad");
        let a = b.param("A", Type::PTR_GLOBAL);
        let i = b.special(SpecialReg::GlobalId(Dim::X));
        // store a u32 register as F32 value
        b.st(AddrSpace::Global, Scalar::F32, Address::indexed(a, i, 4), i.into());
        let e = verify_kernel(&b.finish()).unwrap_err();
        assert!(e.to_string().contains("ST val"));
        // Errors carry the statement path in the analyzer's location
        // language: the store is the second body statement.
        assert!(e.to_string().contains("at body[1]"), "missing stmt path: {e}");
    }

    #[test]
    fn rejects_wrong_space() {
        let mut b = KernelBuilder::new("bad");
        let a = b.param("A", Type::PTR_GLOBAL);
        b.st(AddrSpace::Shared, Scalar::F32, Address::base(a), Operand::Imm(Value::f32(0.0)));
        assert!(verify_kernel(&b.finish()).is_err());
    }

    #[test]
    fn rejects_barrier_under_divergence() {
        let mut b = KernelBuilder::new("bad");
        let t = b.special(SpecialReg::ThreadIdx(Dim::X));
        let p = b.cmp(CmpOp::Lt, Scalar::U32, t.into(), Operand::Imm(Value::u32(1)));
        b.if_(p, |b| b.bar());
        let e = verify_kernel(&b.finish()).unwrap_err();
        assert!(e.to_string().contains("divergent"));
    }

    #[test]
    fn rejects_float_bitops() {
        let mut b = KernelBuilder::new("bad");
        let x = b.reg(Type::F32);
        b.push(Inst::Bin {
            op: BinOp::And,
            ty: Scalar::F32,
            dst: x,
            a: Operand::Imm(Value::f32(1.0)),
            b: Operand::Imm(Value::f32(2.0)),
        });
        assert!(verify_kernel(&b.finish()).is_err());
    }

    #[test]
    fn rejects_break_outside_loop() {
        let mut b = KernelBuilder::new("bad");
        b.brk();
        assert!(verify_kernel(&b.finish()).is_err());
    }

    #[test]
    fn rejects_stale_barrier_ids() {
        let mut b = KernelBuilder::new("bad");
        b.bar();
        let mut k = b.finish();
        // corrupt the id
        k.visit_insts_mut(|i| {
            if let Inst::Bar { id } = i {
                *id = 7;
            }
        });
        assert!(verify_kernel(&k).is_err());
    }
}
