//! AOT artifacts & the translation cache (DESIGN.md §14).
//!
//! The paper's runtime "dynamically translates this IR to the target
//! GPU's native code" — correct, but a warm fleet should never pay that
//! translation twice. This layer closes the loop with two complementary
//! mechanisms, both keyed by the hetIR **content hash**
//! ([`crate::hetir::printer::module_hash`]):
//!
//! 1. **Fat blobs** ([`fatblob`]) — one versioned artifact carrying the
//!    module pre-lowered to every backend ISA (each SIMT config × each
//!    Tensix mode × both JIT tiers) plus the hetIR text itself as the
//!    portable fallback, mirroring the classic fat-binary
//!    cubin-per-arch + PTX scheme with hetIR playing the PTX role.
//!    `HetGpu::load_fat_blob` seeds the JIT cache with zero translation
//!    work; entries that fail validation are skipped individually and
//!    fall back to JIT.
//! 2. **Disk cache** ([`diskcache`]) — an on-disk content-addressed
//!    store shared across processes. JIT misses consult it before
//!    lowering; fresh translations (foreground tier 1 and background
//!    tier 2 alike) persist into it, so a fleet of processes over the
//!    same modules converges to zero compiles. Writes are
//!    atomic-rename, reads take no file locks, and every entry is
//!    checksummed — corrupt or version-mismatched entries read as
//!    misses (re-translate, never crash).
//!
//! The shared [`codec`] serializes a `DeviceProgram` to a little-endian
//! byte payload; both artifact kinds embed those payloads verbatim, so
//! one `CODEC_VERSION` bump invalidates both at once.

pub mod codec;
pub mod diskcache;
pub mod fatblob;

pub use diskcache::{CacheStats, DiskCache, DiskCacheConfig};
pub use fatblob::{build_fat_blob, parse_fat_blob, FatBlob, FatEntry};

/// Version of the `DeviceProgram` byte codec (and therefore of every
/// artifact embedding codec payloads). Bump on ANY change to the ISA
/// enums or program layouts serialized by [`codec`] — stale artifacts
/// then read as misses and the runtime re-translates from hetIR.
pub const CODEC_VERSION: u32 = 1;
