//! On-disk content-addressed translation cache (DESIGN.md §14).
//!
//! One file per translation, named by the FNV-1a-128 hex of the full
//! content-address key `(IR content hash, backend kind, Tensix mode,
//! migratable, tier, codec version, kernel name)`. The cache is shared
//! across processes with no coordination protocol:
//!
//! * **Writes** encode into a process/sequence-unique `.tmp` sibling and
//!   `rename(2)` it into place — readers observe either the old file,
//!   the new file, or no file, never a torn entry.
//! * **Reads** take no file locks: one `read()`, then magic / version /
//!   checksum validation. Anything malformed — truncation, bit flips, a
//!   codec-version bump, a partial write from a crashed peer — counts as
//!   a miss and the entry is deleted best-effort. The runtime then
//!   re-translates from hetIR: fail closed, never crash.
//! * **Eviction** is size-capped LRU by file mtime, run after each
//!   store. The cap comes from `HETGPU_CACHE_MAX_MB` (default 512).
//!
//! The cache is enabled by pointing `HETGPU_CACHE_DIR` at a directory
//! (created on demand), or explicitly via [`DiskCacheConfig`] — both env
//! knobs follow the `HETGPU_SIM_THREADS` warn-once contract: malformed
//! values warn once per process, naming the bad value and the default
//! used, and never fail the run.

use crate::aot::codec::{self, kind_tag, tier_tag};
use crate::aot::CODEC_VERSION;
use crate::backends::{DeviceProgram, JitTier};
use crate::hetir::printer::fnv1a128;
use crate::isa::tensix_isa::TensixMode;
use crate::migrate::blob::mode_tag;
use crate::runtime::device::DeviceKind;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

const MAGIC: &[u8; 4] = b"HGPC";
/// Default size cap when `HETGPU_CACHE_MAX_MB` is unset.
pub const DEFAULT_MAX_MB: u64 = 512;
/// Entry filename extension (scans ignore everything else, so foreign
/// files and in-flight `.tmp` siblings are never evicted or counted).
const EXT: &str = "hgpc";

/// Explicit cache configuration (the programmatic alternative to the
/// `HETGPU_CACHE_DIR` / `HETGPU_CACHE_MAX_MB` env knobs).
#[derive(Debug, Clone)]
pub struct DiskCacheConfig {
    /// Cache directory; created on demand.
    pub dir: PathBuf,
    /// Size cap in MiB; the LRU sweep evicts oldest-mtime entries first.
    pub max_mb: u64,
}

/// Cache observability counters (`HetGpu::cache_stats()`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Entries served (payload validated and decoded).
    pub hits: u64,
    /// Lookups that found nothing usable (absent, corrupt, or stale
    /// version — the last two also delete the offending file).
    pub misses: u64,
    /// Entries written (skipped when the key already exists on disk).
    pub stores: u64,
    /// Entries removed by the LRU size sweep.
    pub evictions: u64,
    /// Current on-disk footprint of the cache directory.
    pub bytes: u64,
}

/// Identity of one translation in the content-address space. Everything
/// that can change the produced program is in here; everything that
/// can't (e.g. `SimtConfig` contents, which are fixed per kind) is not.
#[derive(Debug, Clone, Copy)]
pub struct CacheKey<'a> {
    /// `hetir::printer::module_hash` of the source module.
    pub ir_hash: u128,
    pub kind: DeviceKind,
    pub tensix_mode: Option<TensixMode>,
    /// `TranslateOpts::migratable` — changes emitted Ckpt guards.
    pub migratable: bool,
    pub tier: JitTier,
    pub kernel: &'a str,
}

impl CacheKey<'_> {
    fn file_name(&self) -> String {
        let mut key = Vec::with_capacity(32 + self.kernel.len());
        key.extend_from_slice(&self.ir_hash.to_le_bytes());
        key.push(kind_tag(self.kind));
        key.push(mode_tag(self.tensix_mode));
        key.push(self.migratable as u8);
        key.push(tier_tag(self.tier));
        key.extend_from_slice(&CODEC_VERSION.to_le_bytes());
        key.extend_from_slice(self.kernel.as_bytes());
        format!("{:032x}.{EXT}", fnv1a128(&key))
    }
}

/// The shared cache. All methods are `&self` and lock-free on the file
/// system — concurrency safety rests entirely on atomic rename.
#[derive(Debug)]
pub struct DiskCache {
    dir: PathBuf,
    max_bytes: u64,
    /// Entry-format version stamped into files; parameterized (not the
    /// constant) so tests can prove a version bump invalidates entries.
    version: u32,
    seq: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    stores: AtomicU64,
    evictions: AtomicU64,
}

impl DiskCache {
    /// Open (creating the directory if needed). Fails only when the
    /// directory can't be created — a cache that can't persist is a
    /// configuration error worth surfacing at build time.
    pub fn new(cfg: DiskCacheConfig) -> std::io::Result<DiskCache> {
        std::fs::create_dir_all(&cfg.dir)?;
        Ok(DiskCache {
            dir: cfg.dir,
            max_bytes: cfg.max_mb.saturating_mul(1024 * 1024).max(1),
            version: CODEC_VERSION,
            seq: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            stores: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        })
    }

    /// Test hook: same cache, different stamped format version.
    #[cfg(test)]
    pub(crate) fn with_version(cfg: DiskCacheConfig, version: u32) -> std::io::Result<DiskCache> {
        let mut c = DiskCache::new(cfg)?;
        c.version = version;
        Ok(c)
    }

    /// Cache from the env knobs; `None` when `HETGPU_CACHE_DIR` is unset
    /// (the default: no persistence, pure in-memory JIT) or unusable.
    pub fn from_env() -> Option<DiskCache> {
        let dir = std::env::var("HETGPU_CACHE_DIR").ok()?;
        let dir = dir.trim();
        if dir.is_empty() {
            return None;
        }
        let mut max_mb = DEFAULT_MAX_MB;
        if let Ok(raw) = std::env::var("HETGPU_CACHE_MAX_MB") {
            let (v, warn) = parse_cache_max_mb(&raw);
            max_mb = v;
            if let Some(msg) = warn {
                crate::hetir::analyze::warn_once(&msg);
            }
        }
        match DiskCache::new(DiskCacheConfig { dir: PathBuf::from(dir), max_mb }) {
            Ok(c) => Some(c),
            Err(e) => {
                crate::hetir::analyze::warn_once(&format!(
                    "hetgpu: HETGPU_CACHE_DIR={dir:?} is unusable ({e}); \
                     translation cache disabled for this process"
                ));
                None
            }
        }
    }

    fn path_for(&self, key: &CacheKey) -> PathBuf {
        self.dir.join(key.file_name())
    }

    /// Look up a translation. Lock-free; every failure mode is a miss.
    pub fn load(&self, key: &CacheKey) -> Option<DeviceProgram> {
        let path = self.path_for(key);
        match self.try_load(&path) {
            Some(p) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(p)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    fn try_load(&self, path: &Path) -> Option<DeviceProgram> {
        let bytes = std::fs::read(path).ok()?;
        match self.parse_entry(&bytes) {
            Some(p) => Some(p),
            None => {
                // Corrupt or version-mismatched: reclaim the slot so the
                // follow-up store is not blocked by the exists-check.
                let _ = std::fs::remove_file(path);
                None
            }
        }
    }

    fn parse_entry(&self, bytes: &[u8]) -> Option<DeviceProgram> {
        if bytes.len() < 4 + 4 + 8 + 8 || &bytes[..4] != MAGIC {
            return None;
        }
        let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        if version != self.version {
            return None;
        }
        let sum = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
        let len = u64::from_le_bytes(bytes[16..24].try_into().unwrap()) as usize;
        let payload = bytes.get(24..)?;
        if payload.len() != len || fnv1a128(payload) as u64 != sum {
            return None;
        }
        codec::decode_program(payload).ok()
    }

    /// Persist a translation. Best-effort: IO errors are swallowed (the
    /// cache is an accelerator, not a store of record) and an existing
    /// entry for the key is left untouched.
    pub fn store(&self, key: &CacheKey, prog: &DeviceProgram) {
        let path = self.path_for(key);
        if path.exists() {
            return;
        }
        let payload = codec::encode_program(prog);
        let mut bytes = Vec::with_capacity(24 + payload.len());
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&self.version.to_le_bytes());
        bytes.extend_from_slice(&(fnv1a128(&payload) as u64).to_le_bytes());
        bytes.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        bytes.extend_from_slice(&payload);

        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let tmp = self.dir.join(format!(
            "{}.tmp-{}-{}",
            path.file_name().and_then(|n| n.to_str()).unwrap_or("entry"),
            std::process::id(),
            seq
        ));
        if std::fs::write(&tmp, &bytes).is_err() {
            let _ = std::fs::remove_file(&tmp);
            return;
        }
        if std::fs::rename(&tmp, &path).is_err() {
            let _ = std::fs::remove_file(&tmp);
            return;
        }
        self.stores.fetch_add(1, Ordering::Relaxed);
        self.evict_to_cap();
    }

    /// Scan the directory for cache entries: (path, bytes, mtime).
    fn scan(&self) -> Vec<(PathBuf, u64, std::time::SystemTime)> {
        let mut out = Vec::new();
        let Ok(rd) = std::fs::read_dir(&self.dir) else { return out };
        for entry in rd.flatten() {
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) != Some(EXT) {
                continue;
            }
            let Ok(meta) = entry.metadata() else { continue };
            let mtime = meta.modified().unwrap_or(std::time::SystemTime::UNIX_EPOCH);
            out.push((path, meta.len(), mtime));
        }
        out
    }

    /// LRU sweep: drop oldest-mtime entries until under the byte cap.
    fn evict_to_cap(&self) {
        let mut entries = self.scan();
        let mut total: u64 = entries.iter().map(|(_, len, _)| len).sum();
        if total <= self.max_bytes {
            return;
        }
        entries.sort_by_key(|(_, _, mtime)| *mtime);
        for (path, len, _) in entries {
            if total <= self.max_bytes {
                break;
            }
            if std::fs::remove_file(&path).is_ok() {
                total = total.saturating_sub(len);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Counters plus the current on-disk footprint (one directory scan).
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            stores: self.stores.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            bytes: self.scan().iter().map(|(_, len, _)| len).sum(),
        }
    }
}

/// Parse `HETGPU_CACHE_MAX_MB`. `0` is clamped to 1 MiB (a zero cap
/// would evict every entry as it lands), not an error. Returns the value
/// plus the warning to print for malformed input.
pub fn parse_cache_max_mb(raw: &str) -> (u64, Option<String>) {
    match raw.trim().parse::<u64>() {
        Ok(0) => (1, None),
        Ok(n) => (n, None),
        Err(_) => (
            DEFAULT_MAX_MB,
            Some(format!(
                "hetgpu: HETGPU_CACHE_MAX_MB={raw:?} is not a number; \
                 falling back to the default of {DEFAULT_MAX_MB} MiB"
            )),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backends::{self, TranslateOpts};
    use crate::frontend;
    use crate::hetir::printer::module_hash;
    use crate::isa::simt_isa::SimtConfig;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("hetgpu-diskcache-test-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn sample() -> (u128, DeviceProgram) {
        let src = r#"
__global__ void bump(unsigned* x) {
    unsigned i = blockIdx.x * blockDim.x + threadIdx.x;
    x[i] = x[i] + 1u;
}
"#;
        let m = frontend::compile(src, "cache-test").unwrap();
        let p = backends::translate_simt(
            m.kernel("bump").unwrap(),
            &SimtConfig::nvidia(),
            TranslateOpts::default(),
        )
        .unwrap();
        (module_hash(&m), DeviceProgram::Simt(p))
    }

    fn key(ir_hash: u128) -> CacheKey<'static> {
        CacheKey {
            ir_hash,
            kind: DeviceKind::NvidiaSim,
            tensix_mode: None,
            migratable: true,
            tier: JitTier::Baseline,
            kernel: "bump",
        }
    }

    #[test]
    fn store_then_load_roundtrips() {
        let dir = tmpdir("roundtrip");
        let cache = DiskCache::new(DiskCacheConfig { dir: dir.clone(), max_mb: 64 }).unwrap();
        let (h, prog) = sample();
        assert!(cache.load(&key(h)).is_none());
        cache.store(&key(h), &prog);
        let back = cache.load(&key(h)).expect("stored entry should load");
        assert_eq!(prog, back);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.stores), (1, 1, 1));
        assert!(s.bytes > 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_and_bit_flipped_entries_read_as_misses() {
        let dir = tmpdir("corrupt");
        let cache = DiskCache::new(DiskCacheConfig { dir: dir.clone(), max_mb: 64 }).unwrap();
        let (h, prog) = sample();
        let k = key(h);
        cache.store(&k, &prog);
        let path = cache.path_for(&k);

        // Truncate to half: must fall back, and the file must be removed
        // so a subsequent store can repopulate.
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        assert!(cache.load(&k).is_none());
        assert!(!path.exists(), "corrupt entry should be reclaimed");
        cache.store(&k, &prog);
        assert!(cache.load(&k).is_some());

        // Flip one payload bit: the checksum must catch it.
        let mut evil = std::fs::read(&path).unwrap();
        let last = evil.len() - 1;
        evil[last] ^= 0x01;
        std::fs::write(&path, &evil).unwrap();
        assert!(cache.load(&k).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn version_bump_invalidates_entries() {
        let dir = tmpdir("version");
        let cfg = DiskCacheConfig { dir: dir.clone(), max_mb: 64 };
        let (h, prog) = sample();
        let old = DiskCache::with_version(cfg.clone(), CODEC_VERSION).unwrap();
        old.store(&key(h), &prog);
        assert!(old.load(&key(h)).is_some());
        // Same directory, same key, newer format: stale entry is a miss.
        let new = DiskCache::with_version(cfg, CODEC_VERSION + 1).unwrap();
        assert!(new.load(&key(h)).is_none());
        assert_eq!(new.stats().misses, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lru_sweep_keeps_footprint_under_cap() {
        let dir = tmpdir("lru");
        // 1 MiB floor via the 0-clamp; entries are far smaller, so force
        // eviction by dropping the cap below one entry's size instead.
        let cache = DiskCache::new(DiskCacheConfig { dir: dir.clone(), max_mb: 1 }).unwrap();
        let (h, prog) = sample();
        let entry_bytes = {
            cache.store(&key(h), &prog);
            cache.stats().bytes
        };
        assert!(entry_bytes > 0);
        // Shrink the cap under the entry size and store a second key:
        // the sweep must evict down to at most one entry.
        let mut tight = DiskCache::new(DiskCacheConfig { dir: dir.clone(), max_mb: 1 }).unwrap();
        tight.max_bytes = entry_bytes;
        let mut k2 = key(h);
        k2.kernel = "other";
        tight.store(&k2, &prog);
        let s = tight.stats();
        assert!(s.evictions >= 1, "expected an LRU eviction, stats: {s:?}");
        assert!(s.bytes <= entry_bytes, "footprint {} over cap {}", s.bytes, entry_bytes);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn env_parsers_follow_the_sim_threads_contract() {
        // Valid values parse silently.
        assert_eq!(parse_cache_max_mb("128"), (128, None));
        // 0 clamps (a zero cap would thrash) without warning.
        assert_eq!(parse_cache_max_mb("0"), (1, None));
        // Malformed values fall back to the default and warn, naming the
        // bad value and the default used.
        let (v, warn) = parse_cache_max_mb("lots");
        assert_eq!(v, DEFAULT_MAX_MB);
        let msg = warn.expect("malformed value must warn");
        assert!(msg.contains("lots") && msg.contains("512"), "{msg}");
    }
}
