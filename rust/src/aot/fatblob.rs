//! The fat-blob artifact (DESIGN.md §14): one distributable file that
//! carries a hetIR module pre-lowered to **every** backend ISA — each
//! SIMT vendor config and each Tensix mapping mode, at both JIT tiers —
//! plus the hetIR text itself as the portable fallback. The classic
//! fat-binary scheme (cubin per arch + PTX fallback) with hetIR playing
//! the PTX role: a device the blob wasn't pre-lowered for still loads
//! and JITs from the embedded IR.
//!
//! ```text
//! "HGFB" | u32 codec version
//! | u64 ir_hash lo | u64 ir_hash hi      (hetIR content hash)
//! | string hetIR module text             (portable fallback)
//! | u32 entry count | per entry:
//! |   string kernel | u8 kind | u8 mode | u8 tier | u8 migratable
//! |   u64 payload checksum | bytes payload (aot::codec program)
//! ```
//!
//! **Header-stability contract:** everything through the module text
//! parses identically in every codec version, so a version-mismatched
//! blob still yields the module (marked [`FatBlob::stale`], all entries
//! skipped → pure JIT). Individual entries that fail their checksum or
//! decode are skipped, never fatal — fail closed, re-translate.

use crate::aot::codec::{self, kind_tag, tag_kind, tag_tier, tier_tag};
use crate::aot::CODEC_VERSION;
use crate::backends::{self, DeviceProgram, JitTier, TranslateOpts};
use crate::error::Result;
use crate::hetir::module::Module;
use crate::hetir::printer::{fnv1a128, print_module};
use crate::isa::simt_isa::SimtConfig;
use crate::isa::tensix_isa::TensixMode;
use crate::migrate::blob::{mode_tag, tag_mode, R, W};
use crate::runtime::device::DeviceKind;

const MAGIC: &[u8; 4] = b"HGFB";

/// One pre-lowered translation inside a fat blob.
#[derive(Debug, Clone)]
pub struct FatEntry {
    pub kernel: String,
    pub kind: DeviceKind,
    pub tensix_mode: Option<TensixMode>,
    pub migratable: bool,
    pub tier: JitTier,
    pub prog: DeviceProgram,
}

/// A parsed fat blob: the portable module plus whatever pre-lowered
/// entries survived validation.
#[derive(Debug)]
pub struct FatBlob {
    /// Content hash recorded at build time (equals
    /// `hetir::printer::module_hash(&module)` for an intact blob).
    pub ir_hash: u128,
    pub module: Module,
    pub entries: Vec<FatEntry>,
    /// Entries dropped by validation (checksum, tags, decode, or a
    /// truncated tail). Observability only — skipped targets JIT.
    pub skipped: u32,
    /// True when the blob was built by a different codec version: the
    /// module text is still trusted (header-stability contract) but all
    /// entries were ignored.
    pub stale: bool,
}

/// Every (kind, mode) target the AOT pipeline pre-lowers for. SIMT
/// configs are fixed per kind, so the kind alone names the target.
fn targets() -> Vec<(DeviceKind, Option<TensixMode>)> {
    vec![
        (DeviceKind::NvidiaSim, None),
        (DeviceKind::AmdSim, None),
        (DeviceKind::AmdWave64Sim, None),
        (DeviceKind::IntelSim, None),
        (DeviceKind::TenstorrentSim, Some(TensixMode::VectorSingleCore)),
        (DeviceKind::TenstorrentSim, Some(TensixMode::VectorMultiCore)),
        (DeviceKind::TenstorrentSim, Some(TensixMode::ScalarMimd)),
    ]
}

fn simt_config(kind: DeviceKind) -> Option<SimtConfig> {
    match kind {
        DeviceKind::NvidiaSim => Some(SimtConfig::nvidia()),
        DeviceKind::AmdSim => Some(SimtConfig::amd()),
        DeviceKind::AmdWave64Sim => Some(SimtConfig::amd_wave64()),
        DeviceKind::IntelSim => Some(SimtConfig::intel()),
        DeviceKind::TenstorrentSim => None,
    }
}

/// Pre-lower `m` for every target × both tiers and pack the fat blob.
/// Kernels a backend can't lower (e.g. a Tensix mode the uniformity
/// analysis rejects) are simply absent from the blob — those targets
/// fall back to the embedded hetIR at load time. Migratable builds only:
/// the runtime's launch path always resolves `migratable: true` keys.
pub fn build_fat_blob(m: &Module) -> Result<Vec<u8>> {
    crate::hetir::verify::verify_module(m)?;
    let text = print_module(m);
    let ir_hash = fnv1a128(text.as_bytes());

    let mut entries: Vec<(String, DeviceKind, Option<TensixMode>, JitTier, Vec<u8>)> = Vec::new();
    for kernel in &m.kernels {
        for (kind, mode) in targets() {
            for tier in [JitTier::Baseline, JitTier::Optimized] {
                let opts = TranslateOpts { migratable: true, tier };
                let prog = match (simt_config(kind), mode) {
                    (Some(cfg), None) => backends::translate_simt(kernel, &cfg, opts)
                        .ok()
                        .map(DeviceProgram::Simt),
                    (None, Some(mode)) => backends::translate_tensix(kernel, mode, opts)
                        .ok()
                        .map(DeviceProgram::Tensix),
                    _ => unreachable!("targets() pairs kinds and modes consistently"),
                };
                if let Some(p) = prog {
                    entries.push((kernel.name.clone(), kind, mode, tier, codec::encode_program(&p)));
                }
            }
        }
    }

    let mut w = W::new();
    w.buf.extend_from_slice(MAGIC);
    w.u32(CODEC_VERSION);
    w.u64(ir_hash as u64);
    w.u64((ir_hash >> 64) as u64);
    w.string(&text);
    w.u32(entries.len() as u32);
    for (kernel, kind, mode, tier, payload) in &entries {
        w.string(kernel);
        w.u8(kind_tag(*kind));
        w.u8(mode_tag(*mode));
        w.u8(tier_tag(*tier));
        w.u8(1); // migratable
        w.u64(fnv1a128(payload) as u64);
        w.bytes(payload);
    }
    Ok(w.buf)
}

/// Parse a fat blob. Errors only when the *portable core* (header or
/// module text) is unusable; damaged entries degrade to JIT instead.
pub fn parse_fat_blob(bytes: &[u8]) -> Result<FatBlob> {
    let mut r = R::new(bytes);
    if r.take(4)? != MAGIC {
        return Err(r.err("not a fat blob (bad magic)"));
    }
    let version = r.u32()?;
    let ir_hash = (r.u64()? as u128) | ((r.u64()? as u128) << 64);
    let text = r.string()?;
    let module = crate::hetir::parser::parse_module(&text)?;

    let mut blob = FatBlob { ir_hash, module, entries: Vec::new(), skipped: 0, stale: false };
    if version != CODEC_VERSION {
        // Different codec: entry payloads are unreadable by contract, but
        // the embedded hetIR above is fully usable. Pure-JIT fallback.
        blob.stale = true;
        return Ok(blob);
    }

    let declared = r.count(1)? as u32;
    for parsed in 0..declared {
        // Read the raw fields first so one bad entry never desyncs the
        // stream; validate after.
        let raw = (|| -> Result<(String, u8, u8, u8, u8, u64, Vec<u8>)> {
            Ok((r.string()?, r.u8()?, r.u8()?, r.u8()?, r.u8()?, r.u64()?, r.bytes()?))
        })();
        let Ok((kernel, kt, mt, tt, mig, sum, payload)) = raw else {
            // Truncated tail: everything not yet parsed is lost.
            blob.skipped += declared - parsed;
            break;
        };
        let entry = (|| -> Option<FatEntry> {
            let kind = tag_kind(kt, &r).ok()?;
            let tensix_mode = tag_mode(mt, &r).ok()?;
            let tier = tag_tier(tt, &r).ok()?;
            if fnv1a128(&payload) as u64 != sum {
                return None;
            }
            let prog = codec::decode_program(&payload).ok()?;
            if prog.kernel_name() != kernel {
                return None;
            }
            Some(FatEntry { kernel, kind, tensix_mode, migratable: mig != 0, tier, prog })
        })();
        match entry {
            Some(e) => blob.entries.push(e),
            None => blob.skipped += 1,
        }
    }
    Ok(blob)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend;

    const SRC: &str = r#"
__global__ void axpy(float* x, float* y, float a, unsigned n) {
    unsigned i = blockIdx.x * blockDim.x + threadIdx.x;
    if (i < n) y[i] = a * x[i] + y[i];
}

__global__ void hist(unsigned* bins) {
    unsigned i = blockIdx.x * blockDim.x + threadIdx.x;
    atomicAdd(&bins[i & 7u], 1u);
}
"#;

    fn module() -> Module {
        frontend::compile(SRC, "fatblob-test").unwrap()
    }

    #[test]
    fn build_parse_roundtrip_covers_all_targets() {
        let m = module();
        let bytes = build_fat_blob(&m).unwrap();
        let blob = parse_fat_blob(&bytes).unwrap();
        assert!(!blob.stale);
        assert_eq!(blob.skipped, 0);
        assert_eq!(blob.ir_hash, crate::hetir::printer::module_hash(&blob.module));
        // Two kernels × 4 SIMT kinds × 2 tiers minimum; Tensix modes are
        // best-effort but at least one should lower for these kernels.
        assert!(blob.entries.len() >= 16, "only {} entries", blob.entries.len());
        assert!(blob.entries.iter().any(|e| e.kind == DeviceKind::TenstorrentSim));
        assert!(blob.entries.iter().all(|e| e.migratable));
        // Reparse of the embedded text prints identically (hash-stable).
        assert_eq!(print_module(&blob.module), print_module(&m));
    }

    #[test]
    fn bit_flipped_entry_is_skipped_not_fatal() {
        let m = module();
        let bytes = build_fat_blob(&m).unwrap();
        let intact = parse_fat_blob(&bytes).unwrap();
        // Flip a byte near the end — inside some entry's payload.
        let mut evil = bytes.clone();
        let pos = evil.len() - 9;
        evil[pos] ^= 0x10;
        let blob = parse_fat_blob(&evil).unwrap();
        assert_eq!(blob.entries.len() + blob.skipped as usize, intact.entries.len());
        assert!(blob.skipped >= 1);
    }

    #[test]
    fn truncated_tail_keeps_leading_entries() {
        let m = module();
        let bytes = build_fat_blob(&m).unwrap();
        let intact = parse_fat_blob(&bytes).unwrap();
        let cut = parse_fat_blob(&bytes[..bytes.len() - 40]).unwrap();
        assert!(cut.entries.len() < intact.entries.len());
        assert_eq!(cut.entries.len() + cut.skipped as usize, intact.entries.len());
        for (a, b) in cut.entries.iter().zip(&intact.entries) {
            assert_eq!(a.kernel, b.kernel);
            assert_eq!(a.prog, b.prog);
        }
    }

    #[test]
    fn version_bump_degrades_to_portable_fallback() {
        let m = module();
        let mut bytes = build_fat_blob(&m).unwrap();
        bytes[4] = bytes[4].wrapping_add(1); // codec version lives at [4..8]
        let blob = parse_fat_blob(&bytes).unwrap();
        assert!(blob.stale);
        assert!(blob.entries.is_empty());
        assert_eq!(blob.module.kernels.len(), 2);
    }

    #[test]
    fn garbage_header_is_an_error() {
        assert!(parse_fat_blob(b"nope").is_err());
        assert!(parse_fat_blob(b"HGFB").is_err());
    }
}
