//! Byte codec for translated `DeviceProgram`s — the payload format both
//! the fat blob and the on-disk translation cache embed.
//!
//! Little-endian, hand-rolled on the same `W`/`R` primitives as the
//! snapshot wire format (`migrate::blob`). Every enum gets an explicit
//! tag space (declaration order); unknown tags and truncated payloads
//! decode to `HetError::Blob`, never a panic — callers treat any decode
//! error as a cache miss and re-translate from hetIR.
//!
//! The codec is deliberately *not* self-versioning: artifacts carry
//! [`crate::aot::CODEC_VERSION`] in their headers and refuse payloads
//! from another version before a single payload byte is parsed.

use crate::backends::DeviceProgram;
use crate::error::Result;
use crate::hetir::instr::{BinOp, CmpOp, Dim, FenceScope, Reg as VReg, ShflKind, UnOp, VoteKind};
use crate::hetir::types::{Scalar, Type, Value};
use crate::isa::simt_isa::{DReg, SAddr, SInst, SOp, SSpecial, SStmt, SimtProgram};
use crate::isa::tensix_isa::{So, TAddr, TInst, TSpecial, TStmt, TensixProgram, Vo, SR, VR};
use crate::isa::{CkptSite, DevLoc};
use crate::migrate::blob::{atom_tag, mode_tag, tag_atom, tag_mode, tag_type, type_tag, R, W};

// ---- small enum tag spaces (declaration order) ----

fn scalar_tag(s: Scalar) -> u8 {
    type_tag(Type::Scalar(s))
}

fn tag_scalar(t: u8, r: &R) -> Result<Scalar> {
    match tag_type(t, r)? {
        Type::Scalar(s) => Ok(s),
        Type::Ptr(_) => Err(r.err("pointer type tag where scalar expected")),
    }
}

fn bin_tag(op: BinOp) -> u8 {
    match op {
        BinOp::Add => 0,
        BinOp::Sub => 1,
        BinOp::Mul => 2,
        BinOp::Div => 3,
        BinOp::Rem => 4,
        BinOp::Min => 5,
        BinOp::Max => 6,
        BinOp::And => 7,
        BinOp::Or => 8,
        BinOp::Xor => 9,
        BinOp::Shl => 10,
        BinOp::Shr => 11,
    }
}

fn tag_bin(t: u8, r: &R) -> Result<BinOp> {
    Ok(match t {
        0 => BinOp::Add,
        1 => BinOp::Sub,
        2 => BinOp::Mul,
        3 => BinOp::Div,
        4 => BinOp::Rem,
        5 => BinOp::Min,
        6 => BinOp::Max,
        7 => BinOp::And,
        8 => BinOp::Or,
        9 => BinOp::Xor,
        10 => BinOp::Shl,
        11 => BinOp::Shr,
        _ => return Err(r.err("bad binop tag")),
    })
}

fn un_tag(op: UnOp) -> u8 {
    match op {
        UnOp::Neg => 0,
        UnOp::Not => 1,
        UnOp::Abs => 2,
        UnOp::Sqrt => 3,
        UnOp::Rsqrt => 4,
        UnOp::Exp => 5,
        UnOp::Log => 6,
        UnOp::Sin => 7,
        UnOp::Cos => 8,
        UnOp::Popc => 9,
    }
}

fn tag_un(t: u8, r: &R) -> Result<UnOp> {
    Ok(match t {
        0 => UnOp::Neg,
        1 => UnOp::Not,
        2 => UnOp::Abs,
        3 => UnOp::Sqrt,
        4 => UnOp::Rsqrt,
        5 => UnOp::Exp,
        6 => UnOp::Log,
        7 => UnOp::Sin,
        8 => UnOp::Cos,
        9 => UnOp::Popc,
        _ => return Err(r.err("bad unop tag")),
    })
}

fn cmp_tag(op: CmpOp) -> u8 {
    match op {
        CmpOp::Eq => 0,
        CmpOp::Ne => 1,
        CmpOp::Lt => 2,
        CmpOp::Le => 3,
        CmpOp::Gt => 4,
        CmpOp::Ge => 5,
    }
}

fn tag_cmp(t: u8, r: &R) -> Result<CmpOp> {
    Ok(match t {
        0 => CmpOp::Eq,
        1 => CmpOp::Ne,
        2 => CmpOp::Lt,
        3 => CmpOp::Le,
        4 => CmpOp::Gt,
        5 => CmpOp::Ge,
        _ => return Err(r.err("bad cmpop tag")),
    })
}

fn dim_tag(d: Dim) -> u8 {
    match d {
        Dim::X => 0,
        Dim::Y => 1,
        Dim::Z => 2,
    }
}

fn tag_dim(t: u8, r: &R) -> Result<Dim> {
    Ok(match t {
        0 => Dim::X,
        1 => Dim::Y,
        2 => Dim::Z,
        _ => return Err(r.err("bad dim tag")),
    })
}

fn vote_tag(k: VoteKind) -> u8 {
    match k {
        VoteKind::Any => 0,
        VoteKind::All => 1,
    }
}

fn tag_vote(t: u8, r: &R) -> Result<VoteKind> {
    Ok(match t {
        0 => VoteKind::Any,
        1 => VoteKind::All,
        _ => return Err(r.err("bad vote tag")),
    })
}

fn shfl_tag(k: ShflKind) -> u8 {
    match k {
        ShflKind::Idx => 0,
        ShflKind::Down => 1,
        ShflKind::Up => 2,
        ShflKind::Xor => 3,
    }
}

fn tag_shfl(t: u8, r: &R) -> Result<ShflKind> {
    Ok(match t {
        0 => ShflKind::Idx,
        1 => ShflKind::Down,
        2 => ShflKind::Up,
        3 => ShflKind::Xor,
        _ => return Err(r.err("bad shuffle tag")),
    })
}

fn fence_tag(s: FenceScope) -> u8 {
    match s {
        FenceScope::Block => 0,
        FenceScope::Device => 1,
    }
}

fn tag_fence(t: u8, r: &R) -> Result<FenceScope> {
    Ok(match t {
        0 => FenceScope::Block,
        1 => FenceScope::Device,
        _ => return Err(r.err("bad fence tag")),
    })
}

fn space_tag(s: crate::hetir::types::AddrSpace) -> u8 {
    match s {
        crate::hetir::types::AddrSpace::Global => 0,
        crate::hetir::types::AddrSpace::Shared => 1,
    }
}

fn tag_space(t: u8, r: &R) -> Result<crate::hetir::types::AddrSpace> {
    Ok(match t {
        0 => crate::hetir::types::AddrSpace::Global,
        1 => crate::hetir::types::AddrSpace::Shared,
        _ => return Err(r.err("bad address-space tag")),
    })
}

/// Backend-kind tag — part of artifact keys and the fat-blob entry
/// header, so it must stay stable across releases (append-only).
pub(crate) fn kind_tag(k: crate::runtime::device::DeviceKind) -> u8 {
    use crate::runtime::device::DeviceKind::*;
    match k {
        NvidiaSim => 0,
        AmdSim => 1,
        AmdWave64Sim => 2,
        IntelSim => 3,
        TenstorrentSim => 4,
    }
}

pub(crate) fn tag_kind(t: u8, r: &R) -> Result<crate::runtime::device::DeviceKind> {
    use crate::runtime::device::DeviceKind::*;
    Ok(match t {
        0 => NvidiaSim,
        1 => AmdSim,
        2 => AmdWave64Sim,
        3 => IntelSim,
        4 => TenstorrentSim,
        _ => return Err(r.err("bad device-kind tag")),
    })
}

pub(crate) fn tier_tag(t: crate::backends::JitTier) -> u8 {
    match t {
        crate::backends::JitTier::Baseline => 0,
        crate::backends::JitTier::Optimized => 1,
    }
}

pub(crate) fn tag_tier(t: u8, r: &R) -> Result<crate::backends::JitTier> {
    Ok(match t {
        0 => crate::backends::JitTier::Baseline,
        1 => crate::backends::JitTier::Optimized,
        _ => return Err(r.err("bad tier tag")),
    })
}

// ---- shared leaf encoders ----

fn write_value(w: &mut W, v: Value) {
    w.u8(type_tag(v.ty));
    w.u64(v.bits);
}

fn read_value(r: &mut R) -> Result<Value> {
    let t = r.u8()?;
    let ty = tag_type(t, r)?;
    Ok(Value { bits: r.u64()?, ty })
}

fn write_sop(w: &mut W, op: &SOp) {
    match op {
        SOp::Reg(d) => {
            w.u8(0);
            w.u32(d.0);
        }
        SOp::Imm(v) => {
            w.u8(1);
            write_value(w, *v);
        }
    }
}

fn read_sop(r: &mut R) -> Result<SOp> {
    Ok(match r.u8()? {
        0 => SOp::Reg(DReg(r.u32()?)),
        1 => SOp::Imm(read_value(r)?),
        _ => return Err(r.err("bad simt operand tag")),
    })
}

fn write_saddr(w: &mut W, a: &SAddr) {
    w.u32(a.base.0);
    match a.index {
        None => w.u8(0),
        Some(i) => {
            w.u8(1);
            w.u32(i.0);
        }
    }
    w.u32(a.scale);
    w.i64(a.disp);
}

fn read_saddr(r: &mut R) -> Result<SAddr> {
    let base = DReg(r.u32()?);
    let index = match r.u8()? {
        0 => None,
        1 => Some(DReg(r.u32()?)),
        _ => return Err(r.err("bad simt address index flag")),
    };
    Ok(SAddr { base, index, scale: r.u32()?, disp: r.i64()? })
}

fn write_so(w: &mut W, op: &So) {
    match op {
        So::Reg(s) => {
            w.u8(0);
            w.u16(s.0);
        }
        So::Imm(v) => {
            w.u8(1);
            write_value(w, *v);
        }
    }
}

fn read_so(r: &mut R) -> Result<So> {
    Ok(match r.u8()? {
        0 => So::Reg(SR(r.u16()?)),
        1 => So::Imm(read_value(r)?),
        _ => return Err(r.err("bad tensix scalar operand tag")),
    })
}

fn write_vo(w: &mut W, op: &Vo) {
    match op {
        Vo::Reg(v) => {
            w.u8(0);
            w.u16(v.0);
        }
        Vo::Splat(s) => {
            w.u8(1);
            w.u16(s.0);
        }
        Vo::Imm(v) => {
            w.u8(2);
            write_value(w, *v);
        }
    }
}

fn read_vo(r: &mut R) -> Result<Vo> {
    Ok(match r.u8()? {
        0 => Vo::Reg(VR(r.u16()?)),
        1 => Vo::Splat(SR(r.u16()?)),
        2 => Vo::Imm(read_value(r)?),
        _ => return Err(r.err("bad tensix vector operand tag")),
    })
}

fn write_taddr(w: &mut W, a: &TAddr) {
    w.u16(a.base.0);
    match a.index {
        None => w.u8(0),
        Some(i) => {
            w.u8(1);
            w.u16(i.0);
        }
    }
    w.u32(a.scale);
    w.i64(a.disp);
}

fn read_taddr(r: &mut R) -> Result<TAddr> {
    let base = SR(r.u16()?);
    let index = match r.u8()? {
        0 => None,
        1 => Some(SR(r.u16()?)),
        _ => return Err(r.err("bad tensix address index flag")),
    };
    Ok(TAddr { base, index, scale: r.u32()?, disp: r.i64()? })
}

fn write_devloc(w: &mut W, l: DevLoc) {
    match l {
        DevLoc::SimtReg(n) => {
            w.u8(0);
            w.u32(n);
        }
        DevLoc::TensixScalar(n) => {
            w.u8(1);
            w.u16(n);
        }
        DevLoc::TensixVector(n) => {
            w.u8(2);
            w.u16(n);
        }
    }
}

fn read_devloc(r: &mut R) -> Result<DevLoc> {
    Ok(match r.u8()? {
        0 => DevLoc::SimtReg(r.u32()?),
        1 => DevLoc::TensixScalar(r.u16()?),
        2 => DevLoc::TensixVector(r.u16()?),
        _ => return Err(r.err("bad device-location tag")),
    })
}

fn write_ckpt_site(w: &mut W, s: &CkptSite) {
    w.u32(s.barrier_id);
    w.u32(s.saves.len() as u32);
    for (vreg, ty, loc) in &s.saves {
        w.u32(vreg.0);
        w.u8(type_tag(*ty));
        write_devloc(w, *loc);
    }
}

fn read_ckpt_site(r: &mut R) -> Result<CkptSite> {
    let barrier_id = r.u32()?;
    let n = r.count(7)?;
    let mut saves = Vec::with_capacity(n);
    for _ in 0..n {
        let vreg = VReg(r.u32()?);
        let t = r.u8()?;
        let ty = tag_type(t, r)?;
        saves.push((vreg, ty, read_devloc(r)?));
    }
    Ok(CkptSite { barrier_id, saves })
}

fn write_opt_u16(w: &mut W, v: Option<u16>) {
    match v {
        None => w.u8(0),
        Some(n) => {
            w.u8(1);
            w.u16(n);
        }
    }
}

fn read_opt_u16(r: &mut R) -> Result<Option<u16>> {
    Ok(match r.u8()? {
        0 => None,
        1 => Some(r.u16()?),
        _ => return Err(r.err("bad optional-register flag")),
    })
}

// ---- SIMT statements ----

fn write_sinst(w: &mut W, i: &SInst) {
    match i {
        SInst::Special { dst, kind } => {
            w.u8(0);
            w.u32(dst.0);
            match kind {
                SSpecial::ThreadIdx(d) => {
                    w.u8(0);
                    w.u8(dim_tag(*d));
                }
                SSpecial::BlockIdx(d) => {
                    w.u8(1);
                    w.u8(dim_tag(*d));
                }
                SSpecial::BlockDim(d) => {
                    w.u8(2);
                    w.u8(dim_tag(*d));
                }
                SSpecial::GridDim(d) => {
                    w.u8(3);
                    w.u8(dim_tag(*d));
                }
                SSpecial::LaneId => w.u8(4),
                SSpecial::LinearTid => w.u8(5),
            }
        }
        SInst::Mov { dst, src } => {
            w.u8(1);
            w.u32(dst.0);
            write_sop(w, src);
        }
        SInst::Bin { op, ty, dst, a, b } => {
            w.u8(2);
            w.u8(bin_tag(*op));
            w.u8(scalar_tag(*ty));
            w.u32(dst.0);
            write_sop(w, a);
            write_sop(w, b);
        }
        SInst::Un { op, ty, dst, a } => {
            w.u8(3);
            w.u8(un_tag(*op));
            w.u8(scalar_tag(*ty));
            w.u32(dst.0);
            write_sop(w, a);
        }
        SInst::Fma { ty, dst, a, b, c } => {
            w.u8(4);
            w.u8(scalar_tag(*ty));
            w.u32(dst.0);
            write_sop(w, a);
            write_sop(w, b);
            write_sop(w, c);
        }
        SInst::Cmp { op, ty, dst, a, b } => {
            w.u8(5);
            w.u8(cmp_tag(*op));
            w.u8(scalar_tag(*ty));
            w.u32(dst.0);
            write_sop(w, a);
            write_sop(w, b);
        }
        SInst::Sel { dst, cond, a, b } => {
            w.u8(6);
            w.u32(dst.0);
            write_sop(w, cond);
            write_sop(w, a);
            write_sop(w, b);
        }
        SInst::Cvt { from, to, dst, src } => {
            w.u8(7);
            w.u8(scalar_tag(*from));
            w.u8(scalar_tag(*to));
            w.u32(dst.0);
            write_sop(w, src);
        }
        SInst::PtrAdd { dst, addr } => {
            w.u8(8);
            w.u32(dst.0);
            write_saddr(w, addr);
        }
        SInst::Ld { space, ty, dst, addr } => {
            w.u8(9);
            w.u8(space_tag(*space));
            w.u8(scalar_tag(*ty));
            w.u32(dst.0);
            write_saddr(w, addr);
        }
        SInst::St { space, ty, addr, val } => {
            w.u8(10);
            w.u8(space_tag(*space));
            w.u8(scalar_tag(*ty));
            write_saddr(w, addr);
            write_sop(w, val);
        }
        SInst::Atom { op, space, ty, dst, addr, val, val2 } => {
            w.u8(11);
            w.u8(atom_tag(*op));
            w.u8(space_tag(*space));
            w.u8(scalar_tag(*ty));
            match dst {
                None => w.u8(0),
                Some(d) => {
                    w.u8(1);
                    w.u32(d.0);
                }
            }
            write_saddr(w, addr);
            write_sop(w, val);
            match val2 {
                None => w.u8(0),
                Some(v) => {
                    w.u8(1);
                    write_sop(w, v);
                }
            }
        }
        SInst::BarSync { id } => {
            w.u8(12);
            w.u32(*id);
        }
        SInst::Ckpt { site } => {
            w.u8(13);
            write_ckpt_site(w, site);
        }
        SInst::TeamSync => w.u8(14),
        SInst::Fence { scope } => {
            w.u8(15);
            w.u8(fence_tag(*scope));
        }
        SInst::Vote { kind, dst, src } => {
            w.u8(16);
            w.u8(vote_tag(*kind));
            w.u32(dst.0);
            write_sop(w, src);
        }
        SInst::Ballot { dst, src } => {
            w.u8(17);
            w.u32(dst.0);
            write_sop(w, src);
        }
        SInst::Shfl { kind, ty, dst, val, lane } => {
            w.u8(18);
            w.u8(shfl_tag(*kind));
            w.u8(scalar_tag(*ty));
            w.u32(dst.0);
            write_sop(w, val);
            write_sop(w, lane);
        }
        SInst::Rng { dst, state } => {
            w.u8(19);
            w.u32(dst.0);
            w.u32(state.0);
        }
        SInst::Trap { code } => {
            w.u8(20);
            w.u32(*code);
        }
    }
}

fn read_sinst(r: &mut R) -> Result<SInst> {
    Ok(match r.u8()? {
        0 => {
            let dst = DReg(r.u32()?);
            let kind = match r.u8()? {
                0 => SSpecial::ThreadIdx(tag_dim(r.u8()?, r)?),
                1 => SSpecial::BlockIdx(tag_dim(r.u8()?, r)?),
                2 => SSpecial::BlockDim(tag_dim(r.u8()?, r)?),
                3 => SSpecial::GridDim(tag_dim(r.u8()?, r)?),
                4 => SSpecial::LaneId,
                5 => SSpecial::LinearTid,
                _ => return Err(r.err("bad simt special tag")),
            };
            SInst::Special { dst, kind }
        }
        1 => SInst::Mov { dst: DReg(r.u32()?), src: read_sop(r)? },
        2 => {
            let op = tag_bin(r.u8()?, r)?;
            let t = r.u8()?;
            let ty = tag_scalar(t, r)?;
            SInst::Bin { op, ty, dst: DReg(r.u32()?), a: read_sop(r)?, b: read_sop(r)? }
        }
        3 => {
            let op = tag_un(r.u8()?, r)?;
            let t = r.u8()?;
            let ty = tag_scalar(t, r)?;
            SInst::Un { op, ty, dst: DReg(r.u32()?), a: read_sop(r)? }
        }
        4 => {
            let t = r.u8()?;
            let ty = tag_scalar(t, r)?;
            SInst::Fma {
                ty,
                dst: DReg(r.u32()?),
                a: read_sop(r)?,
                b: read_sop(r)?,
                c: read_sop(r)?,
            }
        }
        5 => {
            let op = tag_cmp(r.u8()?, r)?;
            let t = r.u8()?;
            let ty = tag_scalar(t, r)?;
            SInst::Cmp { op, ty, dst: DReg(r.u32()?), a: read_sop(r)?, b: read_sop(r)? }
        }
        6 => SInst::Sel {
            dst: DReg(r.u32()?),
            cond: read_sop(r)?,
            a: read_sop(r)?,
            b: read_sop(r)?,
        },
        7 => {
            let f = r.u8()?;
            let from = tag_scalar(f, r)?;
            let t = r.u8()?;
            let to = tag_scalar(t, r)?;
            SInst::Cvt { from, to, dst: DReg(r.u32()?), src: read_sop(r)? }
        }
        8 => SInst::PtrAdd { dst: DReg(r.u32()?), addr: read_saddr(r)? },
        9 => {
            let space = tag_space(r.u8()?, r)?;
            let t = r.u8()?;
            let ty = tag_scalar(t, r)?;
            SInst::Ld { space, ty, dst: DReg(r.u32()?), addr: read_saddr(r)? }
        }
        10 => {
            let space = tag_space(r.u8()?, r)?;
            let t = r.u8()?;
            let ty = tag_scalar(t, r)?;
            SInst::St { space, ty, addr: read_saddr(r)?, val: read_sop(r)? }
        }
        11 => {
            let op = tag_atom(r.u8()?, r)?;
            let space = tag_space(r.u8()?, r)?;
            let t = r.u8()?;
            let ty = tag_scalar(t, r)?;
            let dst = match r.u8()? {
                0 => None,
                1 => Some(DReg(r.u32()?)),
                _ => return Err(r.err("bad atomic dst flag")),
            };
            let addr = read_saddr(r)?;
            let val = read_sop(r)?;
            let val2 = match r.u8()? {
                0 => None,
                1 => Some(read_sop(r)?),
                _ => return Err(r.err("bad atomic val2 flag")),
            };
            SInst::Atom { op, space, ty, dst, addr, val, val2 }
        }
        12 => SInst::BarSync { id: r.u32()? },
        13 => SInst::Ckpt { site: read_ckpt_site(r)? },
        14 => SInst::TeamSync,
        15 => SInst::Fence { scope: tag_fence(r.u8()?, r)? },
        16 => {
            let kind = tag_vote(r.u8()?, r)?;
            SInst::Vote { kind, dst: DReg(r.u32()?), src: read_sop(r)? }
        }
        17 => SInst::Ballot { dst: DReg(r.u32()?), src: read_sop(r)? },
        18 => {
            let kind = tag_shfl(r.u8()?, r)?;
            let t = r.u8()?;
            let ty = tag_scalar(t, r)?;
            SInst::Shfl { kind, ty, dst: DReg(r.u32()?), val: read_sop(r)?, lane: read_sop(r)? }
        }
        19 => SInst::Rng { dst: DReg(r.u32()?), state: DReg(r.u32()?) },
        20 => SInst::Trap { code: r.u32()? },
        _ => return Err(r.err("bad simt instruction tag")),
    })
}

fn write_sstmt(w: &mut W, s: &SStmt) {
    match s {
        SStmt::I(i) => {
            w.u8(0);
            write_sinst(w, i);
        }
        SStmt::If { cond, then_b, else_b } => {
            w.u8(1);
            w.u32(cond.0);
            w.u64(*then_b as u64);
            w.u64(*else_b as u64);
        }
        SStmt::Loop { cond, cond_reg, body } => {
            w.u8(2);
            w.u64(*cond as u64);
            w.u32(cond_reg.0);
            w.u64(*body as u64);
        }
        SStmt::Break => w.u8(3),
        SStmt::Continue => w.u8(4),
        SStmt::Return => w.u8(5),
    }
}

fn read_sstmt(r: &mut R) -> Result<SStmt> {
    Ok(match r.u8()? {
        0 => SStmt::I(read_sinst(r)?),
        1 => SStmt::If {
            cond: DReg(r.u32()?),
            then_b: r.u64()? as usize,
            else_b: r.u64()? as usize,
        },
        2 => SStmt::Loop {
            cond: r.u64()? as usize,
            cond_reg: DReg(r.u32()?),
            body: r.u64()? as usize,
        },
        3 => SStmt::Break,
        4 => SStmt::Continue,
        5 => SStmt::Return,
        _ => return Err(r.err("bad simt statement tag")),
    })
}

// ---- Tensix statements ----

fn write_tspecial(w: &mut W, k: &TSpecial) {
    match k {
        TSpecial::BlockIdx(d) => {
            w.u8(0);
            w.u8(dim_tag(*d));
        }
        TSpecial::BlockDim(d) => {
            w.u8(1);
            w.u8(dim_tag(*d));
        }
        TSpecial::GridDim(d) => {
            w.u8(2);
            w.u8(dim_tag(*d));
        }
        TSpecial::CoreSlot => w.u8(3),
        TSpecial::MimdThread(d) => {
            w.u8(4);
            w.u8(dim_tag(*d));
        }
    }
}

fn read_tspecial(r: &mut R) -> Result<TSpecial> {
    Ok(match r.u8()? {
        0 => TSpecial::BlockIdx(tag_dim(r.u8()?, r)?),
        1 => TSpecial::BlockDim(tag_dim(r.u8()?, r)?),
        2 => TSpecial::GridDim(tag_dim(r.u8()?, r)?),
        3 => TSpecial::CoreSlot,
        4 => TSpecial::MimdThread(tag_dim(r.u8()?, r)?),
        _ => return Err(r.err("bad tensix special tag")),
    })
}

fn write_tinst(w: &mut W, i: &TInst) {
    match i {
        TInst::SSpecial { dst, kind } => {
            w.u8(0);
            w.u16(dst.0);
            write_tspecial(w, kind);
        }
        TInst::SMov { dst, src } => {
            w.u8(1);
            w.u16(dst.0);
            write_so(w, src);
        }
        TInst::SBin { op, ty, dst, a, b } => {
            w.u8(2);
            w.u8(bin_tag(*op));
            w.u8(scalar_tag(*ty));
            w.u16(dst.0);
            write_so(w, a);
            write_so(w, b);
        }
        TInst::SUn { op, ty, dst, a } => {
            w.u8(3);
            w.u8(un_tag(*op));
            w.u8(scalar_tag(*ty));
            w.u16(dst.0);
            write_so(w, a);
        }
        TInst::SCmp { op, ty, dst, a, b } => {
            w.u8(4);
            w.u8(cmp_tag(*op));
            w.u8(scalar_tag(*ty));
            w.u16(dst.0);
            write_so(w, a);
            write_so(w, b);
        }
        TInst::SSel { dst, cond, a, b } => {
            w.u8(5);
            w.u16(dst.0);
            write_so(w, cond);
            write_so(w, a);
            write_so(w, b);
        }
        TInst::SCvt { from, to, dst, src } => {
            w.u8(6);
            w.u8(scalar_tag(*from));
            w.u8(scalar_tag(*to));
            w.u16(dst.0);
            write_so(w, src);
        }
        TInst::SFma { ty, dst, a, b, c } => {
            w.u8(7);
            w.u8(scalar_tag(*ty));
            w.u16(dst.0);
            write_so(w, a);
            write_so(w, b);
            write_so(w, c);
        }
        TInst::SRng { dst, state } => {
            w.u8(8);
            w.u16(dst.0);
            w.u16(state.0);
        }
        TInst::SLdLocal { ty, dst, addr } => {
            w.u8(9);
            w.u8(scalar_tag(*ty));
            w.u16(dst.0);
            write_taddr(w, addr);
        }
        TInst::SStLocal { ty, addr, val } => {
            w.u8(10);
            w.u8(scalar_tag(*ty));
            write_taddr(w, addr);
            write_so(w, val);
        }
        TInst::SDmaLd { ty, dst, addr } => {
            w.u8(11);
            w.u8(scalar_tag(*ty));
            w.u16(dst.0);
            write_taddr(w, addr);
        }
        TInst::SDmaSt { ty, addr, val } => {
            w.u8(12);
            w.u8(scalar_tag(*ty));
            write_taddr(w, addr);
            write_so(w, val);
        }
        TInst::SAtom { op, ty, dst, addr, val, val2 } => {
            w.u8(13);
            w.u8(atom_tag(*op));
            w.u8(scalar_tag(*ty));
            write_opt_u16(w, dst.map(|d| d.0));
            write_taddr(w, addr);
            write_so(w, val);
            match val2 {
                None => w.u8(0),
                Some(v) => {
                    w.u8(1);
                    write_so(w, v);
                }
            }
        }
        TInst::DmaIn { local, global, len } => {
            w.u8(14);
            write_taddr(w, local);
            write_taddr(w, global);
            write_so(w, len);
        }
        TInst::DmaOut { local, global, len } => {
            w.u8(15);
            write_taddr(w, local);
            write_taddr(w, global);
            write_so(w, len);
        }
        TInst::VLaneId { dst } => {
            w.u8(16);
            w.u16(dst.0);
        }
        TInst::VMov { dst, src } => {
            w.u8(17);
            w.u16(dst.0);
            write_vo(w, src);
        }
        TInst::VBin { op, ty, dst, a, b } => {
            w.u8(18);
            w.u8(bin_tag(*op));
            w.u8(scalar_tag(*ty));
            w.u16(dst.0);
            write_vo(w, a);
            write_vo(w, b);
        }
        TInst::VUn { op, ty, dst, a } => {
            w.u8(19);
            w.u8(un_tag(*op));
            w.u8(scalar_tag(*ty));
            w.u16(dst.0);
            write_vo(w, a);
        }
        TInst::VFma { ty, dst, a, b, c } => {
            w.u8(20);
            w.u8(scalar_tag(*ty));
            w.u16(dst.0);
            write_vo(w, a);
            write_vo(w, b);
            write_vo(w, c);
        }
        TInst::VCmp { op, ty, dst, a, b } => {
            w.u8(21);
            w.u8(cmp_tag(*op));
            w.u8(scalar_tag(*ty));
            w.u16(dst.0);
            write_vo(w, a);
            write_vo(w, b);
        }
        TInst::VSel { dst, cond, a, b } => {
            w.u8(22);
            w.u16(dst.0);
            write_vo(w, cond);
            write_vo(w, a);
            write_vo(w, b);
        }
        TInst::VCvt { from, to, dst, src } => {
            w.u8(23);
            w.u8(scalar_tag(*from));
            w.u8(scalar_tag(*to));
            w.u16(dst.0);
            write_vo(w, src);
        }
        TInst::VRng { dst, state } => {
            w.u8(24);
            w.u16(dst.0);
            w.u16(state.0);
        }
        TInst::VLdLocal { ty, dst, base, idx, scale, disp } => {
            w.u8(25);
            w.u8(scalar_tag(*ty));
            w.u16(dst.0);
            w.u16(base.0);
            write_opt_u16(w, idx.map(|i| i.0));
            w.u32(*scale);
            w.i64(*disp);
        }
        TInst::VStLocal { ty, base, idx, scale, disp, val } => {
            w.u8(26);
            w.u8(scalar_tag(*ty));
            w.u16(base.0);
            write_opt_u16(w, idx.map(|i| i.0));
            w.u32(*scale);
            w.i64(*disp);
            write_vo(w, val);
        }
        TInst::VDmaGather { ty, dst, base, idx, scale, disp } => {
            w.u8(27);
            w.u8(scalar_tag(*ty));
            w.u16(dst.0);
            w.u16(base.0);
            write_opt_u16(w, idx.map(|i| i.0));
            w.u32(*scale);
            w.i64(*disp);
        }
        TInst::VDmaScatter { ty, base, idx, scale, disp, val } => {
            w.u8(28);
            w.u8(scalar_tag(*ty));
            w.u16(base.0);
            write_opt_u16(w, idx.map(|i| i.0));
            w.u32(*scale);
            w.i64(*disp);
            write_vo(w, val);
        }
        TInst::VAtom { op, ty, dst, base, idx, scale, disp, val, val2, local, shared } => {
            w.u8(29);
            w.u8(atom_tag(*op));
            w.u8(scalar_tag(*ty));
            write_opt_u16(w, dst.map(|d| d.0));
            w.u16(base.0);
            write_opt_u16(w, idx.map(|i| i.0));
            w.u32(*scale);
            w.i64(*disp);
            write_vo(w, val);
            match val2 {
                None => w.u8(0),
                Some(v) => {
                    w.u8(1);
                    write_vo(w, v);
                }
            }
            w.u8(*local as u8);
            w.u8(*shared as u8);
        }
        TInst::VVote { kind, dst, src } => {
            w.u8(30);
            w.u8(vote_tag(*kind));
            w.u16(dst.0);
            write_vo(w, src);
        }
        TInst::VBallot { dst, src } => {
            w.u8(31);
            w.u16(dst.0);
            write_vo(w, src);
        }
        TInst::VShfl { kind, ty, dst, val, lane } => {
            w.u8(32);
            w.u8(shfl_tag(*kind));
            w.u8(scalar_tag(*ty));
            w.u16(dst.0);
            write_vo(w, val);
            write_vo(w, lane);
        }
        TInst::MeshBar { id } => {
            w.u8(33);
            w.u32(*id);
        }
        TInst::MeshVoteAny { dst, src } => {
            w.u8(34);
            w.u16(dst.0);
            write_vo(w, src);
        }
        TInst::Ckpt { site } => {
            w.u8(35);
            write_ckpt_site(w, site);
        }
        TInst::Trap { code } => {
            w.u8(36);
            w.u32(*code);
        }
    }
}

fn read_tinst(r: &mut R) -> Result<TInst> {
    Ok(match r.u8()? {
        0 => TInst::SSpecial { dst: SR(r.u16()?), kind: read_tspecial(r)? },
        1 => TInst::SMov { dst: SR(r.u16()?), src: read_so(r)? },
        2 => {
            let op = tag_bin(r.u8()?, r)?;
            let t = r.u8()?;
            let ty = tag_scalar(t, r)?;
            TInst::SBin { op, ty, dst: SR(r.u16()?), a: read_so(r)?, b: read_so(r)? }
        }
        3 => {
            let op = tag_un(r.u8()?, r)?;
            let t = r.u8()?;
            let ty = tag_scalar(t, r)?;
            TInst::SUn { op, ty, dst: SR(r.u16()?), a: read_so(r)? }
        }
        4 => {
            let op = tag_cmp(r.u8()?, r)?;
            let t = r.u8()?;
            let ty = tag_scalar(t, r)?;
            TInst::SCmp { op, ty, dst: SR(r.u16()?), a: read_so(r)?, b: read_so(r)? }
        }
        5 => {
            TInst::SSel { dst: SR(r.u16()?), cond: read_so(r)?, a: read_so(r)?, b: read_so(r)? }
        }
        6 => {
            let f = r.u8()?;
            let from = tag_scalar(f, r)?;
            let t = r.u8()?;
            let to = tag_scalar(t, r)?;
            TInst::SCvt { from, to, dst: SR(r.u16()?), src: read_so(r)? }
        }
        7 => {
            let t = r.u8()?;
            let ty = tag_scalar(t, r)?;
            TInst::SFma { ty, dst: SR(r.u16()?), a: read_so(r)?, b: read_so(r)?, c: read_so(r)? }
        }
        8 => TInst::SRng { dst: SR(r.u16()?), state: SR(r.u16()?) },
        9 => {
            let t = r.u8()?;
            let ty = tag_scalar(t, r)?;
            TInst::SLdLocal { ty, dst: SR(r.u16()?), addr: read_taddr(r)? }
        }
        10 => {
            let t = r.u8()?;
            let ty = tag_scalar(t, r)?;
            TInst::SStLocal { ty, addr: read_taddr(r)?, val: read_so(r)? }
        }
        11 => {
            let t = r.u8()?;
            let ty = tag_scalar(t, r)?;
            TInst::SDmaLd { ty, dst: SR(r.u16()?), addr: read_taddr(r)? }
        }
        12 => {
            let t = r.u8()?;
            let ty = tag_scalar(t, r)?;
            TInst::SDmaSt { ty, addr: read_taddr(r)?, val: read_so(r)? }
        }
        13 => {
            let op = tag_atom(r.u8()?, r)?;
            let t = r.u8()?;
            let ty = tag_scalar(t, r)?;
            let dst = read_opt_u16(r)?.map(SR);
            let addr = read_taddr(r)?;
            let val = read_so(r)?;
            let val2 = match r.u8()? {
                0 => None,
                1 => Some(read_so(r)?),
                _ => return Err(r.err("bad atomic val2 flag")),
            };
            TInst::SAtom { op, ty, dst, addr, val, val2 }
        }
        14 => TInst::DmaIn { local: read_taddr(r)?, global: read_taddr(r)?, len: read_so(r)? },
        15 => TInst::DmaOut { local: read_taddr(r)?, global: read_taddr(r)?, len: read_so(r)? },
        16 => TInst::VLaneId { dst: VR(r.u16()?) },
        17 => TInst::VMov { dst: VR(r.u16()?), src: read_vo(r)? },
        18 => {
            let op = tag_bin(r.u8()?, r)?;
            let t = r.u8()?;
            let ty = tag_scalar(t, r)?;
            TInst::VBin { op, ty, dst: VR(r.u16()?), a: read_vo(r)?, b: read_vo(r)? }
        }
        19 => {
            let op = tag_un(r.u8()?, r)?;
            let t = r.u8()?;
            let ty = tag_scalar(t, r)?;
            TInst::VUn { op, ty, dst: VR(r.u16()?), a: read_vo(r)? }
        }
        20 => {
            let t = r.u8()?;
            let ty = tag_scalar(t, r)?;
            TInst::VFma { ty, dst: VR(r.u16()?), a: read_vo(r)?, b: read_vo(r)?, c: read_vo(r)? }
        }
        21 => {
            let op = tag_cmp(r.u8()?, r)?;
            let t = r.u8()?;
            let ty = tag_scalar(t, r)?;
            TInst::VCmp { op, ty, dst: VR(r.u16()?), a: read_vo(r)?, b: read_vo(r)? }
        }
        22 => {
            TInst::VSel { dst: VR(r.u16()?), cond: read_vo(r)?, a: read_vo(r)?, b: read_vo(r)? }
        }
        23 => {
            let f = r.u8()?;
            let from = tag_scalar(f, r)?;
            let t = r.u8()?;
            let to = tag_scalar(t, r)?;
            TInst::VCvt { from, to, dst: VR(r.u16()?), src: read_vo(r)? }
        }
        24 => TInst::VRng { dst: VR(r.u16()?), state: VR(r.u16()?) },
        25 => {
            let t = r.u8()?;
            let ty = tag_scalar(t, r)?;
            TInst::VLdLocal {
                ty,
                dst: VR(r.u16()?),
                base: SR(r.u16()?),
                idx: read_opt_u16(r)?.map(VR),
                scale: r.u32()?,
                disp: r.i64()?,
            }
        }
        26 => {
            let t = r.u8()?;
            let ty = tag_scalar(t, r)?;
            TInst::VStLocal {
                ty,
                base: SR(r.u16()?),
                idx: read_opt_u16(r)?.map(VR),
                scale: r.u32()?,
                disp: r.i64()?,
                val: read_vo(r)?,
            }
        }
        27 => {
            let t = r.u8()?;
            let ty = tag_scalar(t, r)?;
            TInst::VDmaGather {
                ty,
                dst: VR(r.u16()?),
                base: SR(r.u16()?),
                idx: read_opt_u16(r)?.map(VR),
                scale: r.u32()?,
                disp: r.i64()?,
            }
        }
        28 => {
            let t = r.u8()?;
            let ty = tag_scalar(t, r)?;
            TInst::VDmaScatter {
                ty,
                base: SR(r.u16()?),
                idx: read_opt_u16(r)?.map(VR),
                scale: r.u32()?,
                disp: r.i64()?,
                val: read_vo(r)?,
            }
        }
        29 => {
            let op = tag_atom(r.u8()?, r)?;
            let t = r.u8()?;
            let ty = tag_scalar(t, r)?;
            let dst = read_opt_u16(r)?.map(VR);
            let base = SR(r.u16()?);
            let idx = read_opt_u16(r)?.map(VR);
            let scale = r.u32()?;
            let disp = r.i64()?;
            let val = read_vo(r)?;
            let val2 = match r.u8()? {
                0 => None,
                1 => Some(read_vo(r)?),
                _ => return Err(r.err("bad atomic val2 flag")),
            };
            let local = r.u8()? != 0;
            let shared = r.u8()? != 0;
            TInst::VAtom { op, ty, dst, base, idx, scale, disp, val, val2, local, shared }
        }
        30 => {
            let kind = tag_vote(r.u8()?, r)?;
            TInst::VVote { kind, dst: SR(r.u16()?), src: read_vo(r)? }
        }
        31 => TInst::VBallot { dst: SR(r.u16()?), src: read_vo(r)? },
        32 => {
            let kind = tag_shfl(r.u8()?, r)?;
            let t = r.u8()?;
            let ty = tag_scalar(t, r)?;
            TInst::VShfl { kind, ty, dst: VR(r.u16()?), val: read_vo(r)?, lane: read_vo(r)? }
        }
        33 => TInst::MeshBar { id: r.u32()? },
        34 => TInst::MeshVoteAny { dst: SR(r.u16()?), src: read_vo(r)? },
        35 => TInst::Ckpt { site: read_ckpt_site(r)? },
        36 => TInst::Trap { code: r.u32()? },
        _ => return Err(r.err("bad tensix instruction tag")),
    })
}

fn write_tstmt(w: &mut W, s: &TStmt) {
    match s {
        TStmt::I(i) => {
            w.u8(0);
            write_tinst(w, i);
        }
        TStmt::SIf { cond, then_b, else_b } => {
            w.u8(1);
            w.u16(cond.0);
            w.u64(*then_b as u64);
            w.u64(*else_b as u64);
        }
        TStmt::VIf { cond, then_b, else_b, always } => {
            w.u8(2);
            w.u16(cond.0);
            w.u64(*then_b as u64);
            w.u64(*else_b as u64);
            w.u8(*always as u8);
        }
        TStmt::SLoop { cond, cond_reg, body } => {
            w.u8(3);
            w.u64(*cond as u64);
            w.u16(cond_reg.0);
            w.u64(*body as u64);
        }
        TStmt::VLoop { cond, cond_reg, body, collective } => {
            w.u8(4);
            w.u64(*cond as u64);
            w.u16(cond_reg.0);
            w.u64(*body as u64);
            write_opt_u16(w, collective.map(|s| s.0));
        }
        TStmt::Break => w.u8(5),
        TStmt::Continue => w.u8(6),
        TStmt::Return => w.u8(7),
    }
}

fn read_tstmt(r: &mut R) -> Result<TStmt> {
    Ok(match r.u8()? {
        0 => TStmt::I(read_tinst(r)?),
        1 => TStmt::SIf {
            cond: SR(r.u16()?),
            then_b: r.u64()? as usize,
            else_b: r.u64()? as usize,
        },
        2 => TStmt::VIf {
            cond: VR(r.u16()?),
            then_b: r.u64()? as usize,
            else_b: r.u64()? as usize,
            always: r.u8()? != 0,
        },
        3 => TStmt::SLoop {
            cond: r.u64()? as usize,
            cond_reg: SR(r.u16()?),
            body: r.u64()? as usize,
        },
        4 => TStmt::VLoop {
            cond: r.u64()? as usize,
            cond_reg: VR(r.u16()?),
            body: r.u64()? as usize,
            collective: read_opt_u16(r)?.map(SR),
        },
        5 => TStmt::Break,
        6 => TStmt::Continue,
        7 => TStmt::Return,
        _ => return Err(r.err("bad tensix statement tag")),
    })
}

// ---- program envelopes ----

/// Serialize a translated program to its byte payload. Infallible —
/// every in-memory program has a wire form.
pub fn encode_program(p: &DeviceProgram) -> Vec<u8> {
    let mut w = W::new();
    match p {
        DeviceProgram::Simt(sp) => {
            w.u8(0);
            w.string(&sp.kernel_name);
            w.u32(sp.num_regs);
            w.u64(sp.shared_bytes);
            w.u32(sp.num_params);
            w.u8(sp.migratable as u8);
            w.u64(sp.entry as u64);
            w.u32(sp.ckpt_sites.len() as u32);
            for site in &sp.ckpt_sites {
                write_ckpt_site(&mut w, site);
            }
            w.u32(sp.blocks.len() as u32);
            for block in &sp.blocks {
                w.u32(block.len() as u32);
                for stmt in block {
                    write_sstmt(&mut w, stmt);
                }
            }
        }
        DeviceProgram::Tensix(tp) => {
            w.u8(1);
            w.string(&tp.kernel_name);
            w.u8(mode_tag(Some(tp.mode)));
            w.u16(tp.num_sregs);
            w.u16(tp.num_vregs);
            w.u64(tp.shared_bytes);
            w.u16(tp.shared_base_sreg.0);
            w.u32(tp.num_params);
            w.u8(tp.migratable as u8);
            w.u64(tp.entry as u64);
            w.u32(tp.ckpt_sites.len() as u32);
            for site in &tp.ckpt_sites {
                write_ckpt_site(&mut w, site);
            }
            w.u32(tp.blocks.len() as u32);
            for block in &tp.blocks {
                w.u32(block.len() as u32);
                for stmt in block {
                    write_tstmt(&mut w, stmt);
                }
            }
        }
    }
    w.buf
}

/// Decode a program payload. Any malformed byte yields `HetError::Blob`;
/// callers fall back to fresh translation.
pub fn decode_program(bytes: &[u8]) -> Result<DeviceProgram> {
    let mut r = R::new(bytes);
    match r.u8()? {
        0 => {
            let kernel_name = r.string()?;
            let num_regs = r.u32()?;
            let shared_bytes = r.u64()?;
            let num_params = r.u32()?;
            let migratable = r.u8()? != 0;
            let entry = r.u64()? as usize;
            let nsites = r.count(8)?;
            let mut ckpt_sites = Vec::with_capacity(nsites);
            for _ in 0..nsites {
                ckpt_sites.push(read_ckpt_site(&mut r)?);
            }
            let nblocks = r.count(4)?;
            let mut blocks = Vec::with_capacity(nblocks);
            for _ in 0..nblocks {
                let nstmts = r.count(1)?;
                let mut block = Vec::with_capacity(nstmts);
                for _ in 0..nstmts {
                    block.push(read_sstmt(&mut r)?);
                }
                blocks.push(block);
            }
            if entry >= blocks.len() {
                return Err(r.err("entry block out of range"));
            }
            Ok(DeviceProgram::Simt(SimtProgram {
                kernel_name,
                blocks,
                entry,
                num_regs,
                shared_bytes,
                num_params,
                ckpt_sites,
                migratable,
            }))
        }
        1 => {
            let kernel_name = r.string()?;
            let mt = r.u8()?;
            let mode = match tag_mode(mt, &r)? {
                Some(m) => m,
                None => return Err(r.err("tensix program missing mode")),
            };
            let num_sregs = r.u16()?;
            let num_vregs = r.u16()?;
            let shared_bytes = r.u64()?;
            let shared_base_sreg = SR(r.u16()?);
            let num_params = r.u32()?;
            let migratable = r.u8()? != 0;
            let entry = r.u64()? as usize;
            let nsites = r.count(8)?;
            let mut ckpt_sites = Vec::with_capacity(nsites);
            for _ in 0..nsites {
                ckpt_sites.push(read_ckpt_site(&mut r)?);
            }
            let nblocks = r.count(4)?;
            let mut blocks = Vec::with_capacity(nblocks);
            for _ in 0..nblocks {
                let nstmts = r.count(1)?;
                let mut block = Vec::with_capacity(nstmts);
                for _ in 0..nstmts {
                    block.push(read_tstmt(&mut r)?);
                }
                blocks.push(block);
            }
            if entry >= blocks.len() {
                return Err(r.err("entry block out of range"));
            }
            Ok(DeviceProgram::Tensix(TensixProgram {
                kernel_name,
                mode,
                blocks,
                entry,
                num_sregs,
                num_vregs,
                shared_bytes,
                shared_base_sreg,
                num_params,
                ckpt_sites,
                migratable,
            }))
        }
        _ => Err(r.err("bad program tag")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backends::{self, JitTier, TranslateOpts};
    use crate::frontend;
    use crate::isa::simt_isa::SimtConfig;
    use crate::isa::tensix_isa::TensixMode;

    /// Exercises branches, loops, barriers (⇒ Ckpt sites), shared memory,
    /// atomics, team ops, and math intrinsics — a broad ISA surface.
    const SRC: &str = r#"
__global__ void stress(float* x, unsigned* bins, unsigned n) {
    __shared__ float stage[64];
    unsigned i = blockIdx.x * blockDim.x + threadIdx.x;
    float acc = 0.0f;
    for (unsigned k = 0u; k < 8u; k++) {
        if (i + k < n) {
            acc += sqrtf(x[i] * 1.5f) + expf(x[i] * 0.001f);
        }
        stage[threadIdx.x & 63u] = acc;
        __syncthreads();
        acc += stage[(threadIdx.x + k) & 63u];
    }
    atomicAdd(&bins[i & 15u], (unsigned)acc);
    x[i] = acc + __shfl_down_sync(0xffffffffu, acc, 1u);
}
"#;

    fn programs() -> Vec<DeviceProgram> {
        let m = frontend::compile(SRC, "codec-test").unwrap();
        let k = m.kernel("stress").unwrap();
        let mut out = Vec::new();
        for cfg in
            [SimtConfig::nvidia(), SimtConfig::amd(), SimtConfig::amd_wave64(), SimtConfig::intel()]
        {
            for tier in [JitTier::Baseline, JitTier::Optimized] {
                let opts = TranslateOpts { migratable: true, tier };
                out.push(DeviceProgram::Simt(backends::translate_simt(k, &cfg, opts).unwrap()));
            }
        }
        for mode in
            [TensixMode::VectorSingleCore, TensixMode::VectorMultiCore, TensixMode::ScalarMimd]
        {
            for tier in [JitTier::Baseline, JitTier::Optimized] {
                let opts = TranslateOpts { migratable: true, tier };
                if let Ok(p) = backends::translate_tensix(k, mode, opts) {
                    out.push(DeviceProgram::Tensix(p));
                }
            }
        }
        out
    }

    #[test]
    fn roundtrips_every_backend_and_tier() {
        let ps = programs();
        assert!(ps.len() >= 8, "expected a broad program set, got {}", ps.len());
        for p in &ps {
            let bytes = encode_program(p);
            let back = decode_program(&bytes).unwrap();
            assert_eq!(*p, back);
        }
    }

    #[test]
    fn truncation_fails_closed_at_every_length() {
        let p = &programs()[0];
        let bytes = encode_program(p);
        // Every proper prefix must produce Err, never panic. Step through
        // a sample of prefix lengths (all of them is O(n²) on big blobs).
        for cut in (0..bytes.len()).step_by(7) {
            assert!(decode_program(&bytes[..cut]).is_err(), "prefix {cut} decoded");
        }
    }

    #[test]
    fn bit_flips_never_panic() {
        let p = &programs()[0];
        let bytes = encode_program(p);
        for pos in (0..bytes.len()).step_by(11) {
            let mut evil = bytes.clone();
            evil[pos] ^= 0x40;
            // Either it decodes to *some* program or errors — both fine;
            // the cache layers above checksum payloads so a silent bit
            // flip can't actually reach the decoder in practice.
            let _ = decode_program(&evil);
        }
    }

    #[test]
    fn bad_program_tag_is_rejected() {
        assert!(decode_program(&[9u8]).is_err());
        assert!(decode_program(&[]).is_err());
    }
}
