//! Streams: ordered asynchronous command queues (paper §4.3 *Kernel and
//! Stream Management*).
//!
//! A [`Stream`] is a **thin recording handle**: every operation appends a
//! node to the runtime's event graph ([`crate::runtime::events`]) and
//! returns immediately; a shared executor pool drains ready nodes onto the
//! block-dispatch pool, so independent streams overlap while each stream's
//! own commands retain FIFO order. When a launch is paused by the
//! cooperative checkpoint protocol the stream **halts**: subsequent
//! commands are deferred "until migration completes" (paper §4.3) and the
//! harvested state waits for the orchestrator; `resume` (possibly naming a
//! different device) re-enters the kernel from its snapshot, then the
//! deferred queue drains in order.

use crate::error::Result;
use crate::runtime::events::{EventGraph, EventId, NodeKind};
use crate::runtime::launch::LaunchSpec;
use crate::sim::snapshot::{BlockResume, BlockState, CostReport};
use std::sync::Arc;

/// A kernel frozen mid-execution by a checkpoint.
#[derive(Debug, Clone)]
pub struct PausedKernel {
    pub spec: LaunchSpec,
    /// Per-block states (captured registers / not-started / done).
    pub blocks: Vec<BlockState>,
}

impl PausedKernel {
    /// Build the per-block resume directives for a new launch.
    pub fn resume_directives(&self) -> Vec<BlockResume> {
        self.blocks
            .iter()
            .map(|b| match b {
                BlockState::NotStarted => BlockResume::FromEntry,
                BlockState::Done => BlockResume::Skip,
                BlockState::Suspended(cap) => BlockResume::FromBarrier(cap.clone()),
            })
            .collect()
    }
}

/// Per-device slice of a stream's accumulated statistics. A stream that
/// migrated (or whose shards ran on several devices within one
/// synchronize window) reports one entry per device it executed on.
#[derive(Debug, Clone, Default)]
pub struct PerDeviceStats {
    pub device: usize,
    pub launches: u64,
    pub completed: u64,
    /// Dispatch worker threads of that device's engine.
    pub sim_workers: usize,
    pub cost: CostReport,
    pub wall_micros: f64,
}

/// Accumulated per-stream statistics.
#[derive(Debug, Clone, Default)]
pub struct StreamStats {
    pub launches: u64,
    pub completed: u64,
    pub cost: CostReport,
    pub wall_micros: f64,
    /// Dispatch worker threads of the device the most recent launch ran on
    /// (1 = sequential block execution). See `per_device` for the full
    /// breakdown when launches spread over several devices.
    pub sim_workers: usize,
    /// Per-device breakdown, ordered by first use.
    pub per_device: Vec<PerDeviceStats>,
}

impl StreamStats {
    /// Fold one executed launch into the totals and its device's slice.
    pub(crate) fn record_launch(
        &mut self,
        device: usize,
        workers: usize,
        wall_us: f64,
        cost: &CostReport,
        completed: bool,
    ) {
        self.launches += 1;
        self.wall_micros += wall_us;
        self.sim_workers = workers;
        self.cost.merge(cost);
        if completed {
            self.completed += 1;
        }
        let idx = match self.per_device.iter().position(|d| d.device == device) {
            Some(i) => i,
            None => {
                self.per_device.push(PerDeviceStats { device, ..Default::default() });
                self.per_device.len() - 1
            }
        };
        let slot = &mut self.per_device[idx];
        slot.launches += 1;
        slot.wall_micros += wall_us;
        slot.sim_workers = workers;
        slot.cost.merge(cost);
        if completed {
            slot.completed += 1;
        }
    }
}

/// Host-side handle to a stream: an id plus the graph it records into.
/// Cheap to clone — all state lives in the graph.
#[derive(Clone)]
pub struct Stream {
    pub id: usize,
    graph: Arc<EventGraph>,
}

impl Stream {
    pub(crate) fn new(id: usize, graph: Arc<EventGraph>) -> Stream {
        Stream { id, graph }
    }

    /// Record a kernel launch; returns its event.
    pub fn launch(&self, spec: LaunchSpec) -> Result<EventId> {
        self.graph.enqueue(self.id, NodeKind::Launch { spec, shard: None }, &[])
    }

    pub(crate) fn enqueue(&self, kind: NodeKind, deps: &[EventId]) -> Result<EventId> {
        self.graph.enqueue(self.id, kind, deps)
    }

    /// Wait for all runnable queued work; surfaces the sticky error if any.
    pub fn synchronize(&self) -> Result<()> {
        self.graph.synchronize(self.id)
    }

    /// Wait for the queue and report whether the stream is halted at a
    /// checkpoint (used by the migration orchestrator).
    pub fn quiesce(&self) -> Result<bool> {
        self.graph.quiesce(self.id)
    }

    /// Take the paused kernel (leaves the stream halted).
    pub fn take_paused(&self) -> Result<Option<PausedKernel>> {
        self.graph.take_paused(self.id)
    }

    /// Resume on `device` with optional restored kernel state. The device
    /// is validated before anything is acknowledged; re-entry itself runs
    /// asynchronously and drains the deferred queue in FIFO order.
    pub fn resume(&self, device: usize, paused: Option<PausedKernel>) -> Result<()> {
        self.graph.resume(self.id, device, paused)
    }

    /// Device this stream currently records against.
    pub fn device(&self) -> Result<usize> {
        self.graph.stream_device(self.id)
    }

    /// Snapshot of the accumulated statistics.
    pub fn stats(&self) -> Result<StreamStats> {
        self.graph.stats(self.id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_accumulate_per_device() {
        let mut s = StreamStats::default();
        let c = CostReport { warp_instructions: 10, ..Default::default() };
        s.record_launch(0, 4, 5.0, &c, true);
        s.record_launch(1, 2, 7.0, &c, true);
        s.record_launch(0, 4, 1.0, &c, false);
        assert_eq!(s.launches, 3);
        assert_eq!(s.completed, 2);
        assert_eq!(s.cost.warp_instructions, 30);
        assert_eq!(s.sim_workers, 4, "last launch ran on device 0");
        assert_eq!(s.per_device.len(), 2);
        let d0 = &s.per_device[0];
        assert_eq!((d0.device, d0.launches, d0.completed, d0.sim_workers), (0, 2, 1, 4));
        assert_eq!(d0.cost.warp_instructions, 20);
        let d1 = &s.per_device[1];
        assert_eq!((d1.device, d1.launches, d1.sim_workers), (1, 1, 2));
    }
}
