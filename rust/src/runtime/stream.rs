//! Streams: ordered asynchronous command queues, one worker thread each
//! (paper §4.3 *Kernel and Stream Management*).
//!
//! A stream executes launches in order on its bound device. When a launch
//! is paused by the cooperative checkpoint protocol, the stream **halts**:
//! subsequent launches are deferred "until migration completes" (paper
//! §4.3), and the harvested state waits for the orchestrator. A `Resume`
//! command (possibly naming a different device) re-enters the kernel from
//! its snapshot and then drains the deferred queue.

use crate::error::{HetError, Result};
use crate::runtime::launch::LaunchSpec;
use crate::runtime::RuntimeInner;
use crate::sim::snapshot::{BlockResume, BlockState, CostReport, LaunchOutcome};
use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// A kernel frozen mid-execution by a checkpoint.
#[derive(Debug, Clone)]
pub struct PausedKernel {
    pub spec: LaunchSpec,
    /// Per-block states (captured registers / not-started / done).
    pub blocks: Vec<BlockState>,
}

impl PausedKernel {
    /// Build the per-block resume directives for a new launch.
    pub fn resume_directives(&self) -> Vec<BlockResume> {
        self.blocks
            .iter()
            .map(|b| match b {
                BlockState::NotStarted => BlockResume::FromEntry,
                BlockState::Done => BlockResume::Skip,
                BlockState::Suspended(cap) => BlockResume::FromBarrier(cap.clone()),
            })
            .collect()
    }
}

/// Accumulated per-stream statistics.
#[derive(Debug, Clone, Default)]
pub struct StreamStats {
    pub launches: u64,
    pub completed: u64,
    pub cost: CostReport,
    pub wall_micros: f64,
    /// Dispatch worker threads of the device the last launch ran on
    /// (1 = sequential block execution).
    pub sim_workers: usize,
}

pub enum Cmd {
    Launch(LaunchSpec),
    /// Fence: acknowledged once all prior commands were processed;
    /// returns (sticky error, halted?).
    Barrier(Sender<(Option<String>, bool)>),
    /// Hand the paused kernel to the orchestrator (leaves the stream
    /// halted until `Resume`).
    TakePaused(Sender<Option<PausedKernel>>),
    /// Re-enter a paused kernel (possibly on a new device), or just
    /// un-halt if `paused` is `None`.
    Resume { device: usize, paused: Option<Box<PausedKernel>>, ack: Sender<Result<()>> },
    Shutdown,
}

/// Host-side handle to a stream.
pub struct Stream {
    pub id: usize,
    tx: Sender<Cmd>,
    pub stats: Arc<Mutex<StreamStats>>,
    handle: Option<JoinHandle<()>>,
}

impl Stream {
    pub fn spawn(id: usize, device: usize, inner: Arc<RuntimeInner>) -> Stream {
        let (tx, rx) = channel();
        let stats = Arc::new(Mutex::new(StreamStats::default()));
        let stats2 = stats.clone();
        let handle = std::thread::Builder::new()
            .name(format!("hetgpu-stream-{id}"))
            .spawn(move || worker(device, inner, rx, stats2))
            .expect("spawn stream worker");
        Stream { id, tx, stats, handle: Some(handle) }
    }

    pub fn send(&self, cmd: Cmd) -> Result<()> {
        self.tx.send(cmd).map_err(|_| HetError::runtime("stream worker died"))
    }

    /// Wait for all queued work; surfaces the sticky error if any.
    pub fn synchronize(&self) -> Result<()> {
        let (ack, rx) = channel();
        self.send(Cmd::Barrier(ack))?;
        let (err, _halted) =
            rx.recv().map_err(|_| HetError::runtime("stream worker died"))?;
        match err {
            Some(e) => Err(HetError::runtime(format!("stream {}: {e}", self.id))),
            None => Ok(()),
        }
    }

    /// Wait for the queue and report whether the stream is halted at a
    /// checkpoint (used by the migration orchestrator).
    pub fn quiesce(&self) -> Result<bool> {
        let (ack, rx) = channel();
        self.send(Cmd::Barrier(ack))?;
        let (err, halted) =
            rx.recv().map_err(|_| HetError::runtime("stream worker died"))?;
        if let Some(e) = err {
            return Err(HetError::runtime(format!("stream {}: {e}", self.id)));
        }
        Ok(halted)
    }

    /// Take the paused kernel (leaves the stream halted).
    pub fn take_paused(&self) -> Result<Option<PausedKernel>> {
        let (ack, rx) = channel();
        self.send(Cmd::TakePaused(ack))?;
        rx.recv().map_err(|_| HetError::runtime("stream worker died"))
    }

    /// Resume on `device` with optional restored kernel state.
    pub fn resume(&self, device: usize, paused: Option<PausedKernel>) -> Result<()> {
        let (ack, rx) = channel();
        self.send(Cmd::Resume { device, paused: paused.map(Box::new), ack })?;
        rx.recv().map_err(|_| HetError::runtime("stream worker died"))?
    }
}

impl Drop for Stream {
    fn drop(&mut self) {
        let _ = self.tx.send(Cmd::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn worker(
    mut device: usize,
    inner: Arc<RuntimeInner>,
    rx: Receiver<Cmd>,
    stats: Arc<Mutex<StreamStats>>,
) {
    let mut deferred: VecDeque<LaunchSpec> = VecDeque::new();
    let mut paused: Option<PausedKernel> = None;
    let mut halted = false;
    let mut sticky_error: Option<String> = None;

    let exec = |device: usize,
                spec: &LaunchSpec,
                resume: Option<&[BlockResume]>,
                stats: &Mutex<StreamStats>|
     -> Result<Option<PausedKernel>> {
        let t0 = Instant::now();
        let outcome = inner.run_launch(device, spec, resume)?;
        let wall = t0.elapsed().as_secs_f64() * 1e6;
        let workers = inner.device(device).map(|d| d.engine.workers()).unwrap_or(1);
        let mut s = stats.lock().unwrap();
        s.launches += 1;
        s.wall_micros += wall;
        s.sim_workers = workers;
        s.cost.merge(outcome.cost());
        match outcome {
            LaunchOutcome::Completed(_) => {
                s.completed += 1;
                Ok(None)
            }
            LaunchOutcome::Paused { grid, .. } => {
                Ok(Some(PausedKernel { spec: spec.clone(), blocks: grid.blocks }))
            }
        }
    };

    loop {
        // Drain deferred work first when running normally.
        if !halted && sticky_error.is_none() {
            if let Some(spec) = deferred.pop_front() {
                match exec(device, &spec, None, &stats) {
                    Ok(Some(p)) => {
                        paused = Some(p);
                        halted = true;
                    }
                    Ok(None) => {}
                    Err(e) => sticky_error = Some(e.to_string()),
                }
                continue;
            }
        }
        let cmd = match rx.recv() {
            Ok(c) => c,
            Err(_) => return,
        };
        match cmd {
            Cmd::Launch(spec) => {
                if halted || sticky_error.is_some() {
                    deferred.push_back(spec);
                } else {
                    match exec(device, &spec, None, &stats) {
                        Ok(Some(p)) => {
                            paused = Some(p);
                            halted = true;
                        }
                        Ok(None) => {}
                        Err(e) => sticky_error = Some(e.to_string()),
                    }
                }
            }
            Cmd::Barrier(ack) => {
                let _ = ack.send((sticky_error.clone(), halted));
            }
            Cmd::TakePaused(ack) => {
                let _ = ack.send(paused.take());
            }
            Cmd::Resume { device: dev, paused: pk, ack } => {
                device = dev;
                // Acknowledge before executing: migration is considered
                // complete once the kernel is re-entered; the caller can
                // trigger another checkpoint while it runs (the chained
                // H100→AMD→Tenstorrent scenario of §6.3). Errors surface
                // as sticky stream errors at the next synchronize.
                let _ = ack.send(Ok(()));
                match pk {
                    Some(pk) => {
                        let dirs = pk.resume_directives();
                        match exec(device, &pk.spec, Some(&dirs), &stats) {
                            Ok(Some(p2)) => {
                                // Paused again mid-resume (double migration).
                                paused = Some(p2);
                                halted = true;
                            }
                            Ok(None) => halted = false,
                            Err(e) => sticky_error = Some(e.to_string()),
                        }
                    }
                    None => halted = false,
                }
            }
            Cmd::Shutdown => return,
        }
    }
}
