//! Stream handles and per-stream state shared with the event graph
//! (paper §4.3 *Kernel and Stream Management*).
//!
//! A stream is an ordered asynchronous command queue living entirely
//! inside the runtime's event graph ([`crate::runtime::events`]); the
//! host side only ever holds a [`StreamHandle`] — a generational
//! `{slot, generation}` pair minted by `HetGpu::create_stream` and
//! invalidated by `HetGpu::destroy_stream`. Every API call revalidates
//! the handle against the graph's slot table, so use-after-destroy and
//! slot reuse surface as `HetError::InvalidHandle` rather than aliasing
//! whichever stream reused the slot.
//!
//! This module also holds [`PausedKernel`] (the captured mid-execution
//! kernel a checkpoint harvests) and [`StreamStats`] (per-stream
//! accounting), both of which the migration and coordinator layers share.

use crate::runtime::handle::impl_handle_raw;
use crate::runtime::launch::LaunchSpec;
use crate::sim::snapshot::{BlockResume, BlockState, CostReport};

/// Generational handle to a stream (API v2).
///
/// `Copy` and cheap; the `{slot, generation}` pair is validated on every
/// use. Handles survive migration (the stream keeps its identity while
/// its device binding changes) and go stale on `destroy_stream`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct StreamHandle {
    pub(crate) slot: u32,
    pub(crate) gen: u32,
}

impl StreamHandle {
    pub(crate) fn new(slot: u32, gen: u32) -> StreamHandle {
        StreamHandle { slot, gen }
    }
}

impl_handle_raw!(StreamHandle, "stream");

/// A kernel frozen mid-execution by a checkpoint.
#[derive(Debug, Clone)]
pub struct PausedKernel {
    pub spec: LaunchSpec,
    /// Per-block states (captured registers / not-started / done).
    pub blocks: Vec<BlockState>,
    /// The cross-shard atomics journal of a journaled coordinator shard
    /// (`None` for plain launches). Riding inside the paused kernel keeps
    /// journal continuity through every resume path — including streams
    /// collaterally halted by a co-located checkpoint. Not serialized:
    /// the wire blob carries the *entries* (`Snapshot::journal`); the
    /// restoring side attaches a fresh journal.
    pub journal: Option<std::sync::Arc<crate::delta::journal::AtomicJournal>>,
    /// The device the kernel was suspended on — the pin below is only
    /// valid there (a cross-device resume must re-translate for the new
    /// target anyway).
    pub device: usize,
    /// The exact translation the kernel was suspended under, pinned so a
    /// same-device resume runs it even if the tiered JIT swapped the
    /// cache entry while the kernel was paused. `None` after a wire
    /// restore — blobs don't carry programs; the restoring context
    /// re-resolves, which is safe because both tiers agree on every
    /// barrier's register state and suspension metadata (DESIGN.md §11).
    pub prog: Option<std::sync::Arc<crate::backends::DeviceProgram>>,
    /// Observability root span id of the launch this kernel belongs to
    /// (0 when tracing was disarmed), so spans of a resume — possibly on
    /// another device, after a rebalance — join the original launch's
    /// tree. Not serialized: a wire-restored kernel starts a fresh tree.
    pub trace: u64,
}

impl PausedKernel {
    /// Build the per-block resume directives for a new launch.
    pub fn resume_directives(&self) -> Vec<BlockResume> {
        self.blocks
            .iter()
            .map(|b| match b {
                BlockState::NotStarted => BlockResume::FromEntry,
                BlockState::Done => BlockResume::Skip,
                BlockState::Suspended(cap) => BlockResume::FromBarrier(cap.clone()),
            })
            .collect()
    }
}

/// Per-device slice of a stream's accumulated statistics. A stream that
/// migrated (or whose shards ran on several devices within one
/// synchronize window) reports one entry per device it executed on.
#[derive(Debug, Clone, Default)]
pub struct PerDeviceStats {
    pub device: usize,
    pub launches: u64,
    pub completed: u64,
    /// Dispatch worker threads of that device's engine.
    pub sim_workers: usize,
    pub cost: CostReport,
    /// Wall time spent *executing* on this device (busy time).
    pub wall_micros: f64,
    /// Wall time this device's launches spent queued in the event graph
    /// before an executor picked them (enqueue → pickup) — the other half
    /// of the busy-vs-queued breakdown.
    pub queued_micros: f64,
}

/// Accumulated per-stream statistics.
#[derive(Debug, Clone, Default)]
pub struct StreamStats {
    pub launches: u64,
    pub completed: u64,
    pub cost: CostReport,
    /// Total busy wall time (executing launches), summed over devices.
    pub wall_micros: f64,
    /// Total queued wall time (enqueue → executor pickup), summed over
    /// devices — busy vs. queued per phase of a launch's life; the
    /// per-device slices carry the breakdown.
    pub queued_micros: f64,
    /// Dispatch worker threads of the device the most recent launch ran on
    /// (1 = sequential block execution). See `per_device` for the full
    /// breakdown when launches spread over several devices.
    pub sim_workers: usize,
    /// Per-device breakdown, ordered by first use.
    pub per_device: Vec<PerDeviceStats>,
}

impl StreamStats {
    /// Fold one executed launch into the totals and its device's slice.
    /// `wall_us` is the execution (busy) time; `queued_us` is how long
    /// the node sat in the event graph before an executor picked it.
    pub(crate) fn record_launch(
        &mut self,
        device: usize,
        workers: usize,
        wall_us: f64,
        queued_us: f64,
        cost: &CostReport,
        completed: bool,
    ) {
        self.launches += 1;
        self.wall_micros += wall_us;
        self.queued_micros += queued_us;
        self.sim_workers = workers;
        self.cost.merge(cost);
        if completed {
            self.completed += 1;
        }
        let idx = match self.per_device.iter().position(|d| d.device == device) {
            Some(i) => i,
            None => {
                self.per_device.push(PerDeviceStats { device, ..Default::default() });
                self.per_device.len() - 1
            }
        };
        let slot = &mut self.per_device[idx];
        slot.launches += 1;
        slot.wall_micros += wall_us;
        slot.queued_micros += queued_us;
        slot.sim_workers = workers;
        slot.cost.merge(cost);
        if completed {
            slot.completed += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_accumulate_per_device() {
        let mut s = StreamStats::default();
        let c = CostReport { warp_instructions: 10, ..Default::default() };
        s.record_launch(0, 4, 5.0, 0.5, &c, true);
        s.record_launch(1, 2, 7.0, 0.25, &c, true);
        s.record_launch(0, 4, 1.0, 0.5, &c, false);
        assert_eq!(s.launches, 3);
        assert_eq!(s.completed, 2);
        assert_eq!(s.cost.warp_instructions, 30);
        assert_eq!(s.sim_workers, 4, "last launch ran on device 0");
        assert_eq!(s.wall_micros, 13.0);
        assert_eq!(s.queued_micros, 1.25, "queued time accumulates separately from busy");
        assert_eq!(s.per_device.len(), 2);
        let d0 = &s.per_device[0];
        assert_eq!((d0.device, d0.launches, d0.completed, d0.sim_workers), (0, 2, 1, 4));
        assert_eq!(d0.cost.warp_instructions, 20);
        assert_eq!((d0.wall_micros, d0.queued_micros), (6.0, 1.0));
        let d1 = &s.per_device[1];
        assert_eq!((d1.device, d1.launches, d1.sim_workers), (1, 1, 2));
        assert_eq!((d1.wall_micros, d1.queued_micros), (7.0, 0.25));
    }

    #[test]
    fn handle_raw_roundtrip() {
        let h = StreamHandle::new(7, 42);
        assert_eq!(StreamHandle::from_raw(h.raw()), h);
        assert_eq!(format!("{h}"), "stream#7.42");
    }
}
