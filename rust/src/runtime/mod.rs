//! The hetGPU runtime (paper §4.2): device registry, unified memory,
//! JIT translation cache, event-graph streams ([`events`]), kernel launch,
//! generational handle tables (`runtime::handle`), and the execution entry
//! point shared by fresh launches, coordinator shards, and migration
//! resumes.

pub mod api;
pub mod device;
pub mod events;
pub mod faultinject;
pub(crate) mod handle;
pub mod jit;
pub mod launch;
pub mod memory;
pub mod stream;

use crate::delta::journal::AtomicJournal;
use crate::error::{HetError, Result};
use crate::hetir::module::Module;
use crate::isa::tensix_isa::TensixMode;
use crate::isa::AtomicsClass;
use crate::runtime::device::{Device, DeviceKind, Engine};
use crate::runtime::faultinject::FaultInjector;
use crate::runtime::handle::SlotTable;
use crate::runtime::jit::{JitCache, JitKey, JitMemo};
use crate::runtime::launch::{args_to_values, choose_tensix_mode, validate_dims, LaunchSpec};
use crate::runtime::memory::MemoryManager;
use crate::sim::snapshot::{BlockResume, LaunchOutcome};
use std::sync::{Mutex, RwLock};

/// Generational handle to a loaded hetIR module (API v2).
///
/// Minted by `HetGpu::load_module` (and the compile front-ends),
/// invalidated by `HetGpu::unload_module`; stale handles — including
/// launches already queued when the module was unloaded — fail with
/// `HetError::InvalidHandle` instead of silently resolving whichever
/// module reused the slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ModuleHandle {
    pub(crate) slot: u32,
    pub(crate) gen: u32,
}

handle::impl_handle_raw!(ModuleHandle, "module");

/// A loaded module plus the process-unique id the JIT cache keys on.
struct LoadedModule {
    module: Module,
    uid: u64,
}

/// Generational registry of loaded modules.
#[derive(Default)]
pub struct ModuleTable {
    table: SlotTable<LoadedModule>,
    next_uid: u64,
}

impl ModuleTable {
    pub fn new() -> ModuleTable {
        ModuleTable { table: SlotTable::new(), next_uid: 0 }
    }

    pub(crate) fn insert(&mut self, module: Module) -> ModuleHandle {
        let uid = self.next_uid;
        self.next_uid += 1;
        let (slot, gen) = self.table.insert(LoadedModule { module, uid });
        ModuleHandle { slot, gen }
    }

    /// Resolve a handle → `(module, uid)`; stale handles miss with
    /// [`HetError::InvalidHandle`].
    pub(crate) fn get(&self, h: ModuleHandle) -> Result<(&Module, u64)> {
        self.table
            .get(h.slot, h.gen)
            .map(|m| (&m.module, m.uid))
            .ok_or_else(|| {
                HetError::invalid_handle("module", "module was unloaded or never loaded")
            })
    }

    /// Unload a module; returns its uid for JIT-cache eviction.
    pub(crate) fn remove(&mut self, h: ModuleHandle) -> Result<u64> {
        self.table
            .remove(h.slot, h.gen)
            .map(|m| m.uid)
            .ok_or_else(|| {
                HetError::invalid_handle("module", "module was unloaded or never loaded")
            })
    }

    /// Number of loaded modules.
    pub fn live(&self) -> usize {
        self.table.live()
    }
}

/// Shared state behind a [`api::HetGpu`] context.
pub struct RuntimeInner {
    pub devices: Vec<Device>,
    pub modules: RwLock<ModuleTable>,
    pub jit: JitCache,
    pub memory: MemoryManager,
    /// Deterministic fault-injection plane (inert unless a plan is
    /// installed) plus the fault/recovery observability counters.
    pub fault: FaultInjector,
}

impl RuntimeInner {
    pub fn device(&self, id: usize) -> Result<&Device> {
        self.devices.get(id).ok_or_else(|| HetError::runtime(format!("no device {id}")))
    }

    /// Execute `spec` on `device_id`, optionally resuming from per-block
    /// directives. This is the single execution path used by streams and
    /// by the migration orchestrator — fresh launch and cross-device
    /// resume differ only in `resume`. The module handle is revalidated
    /// here: a launch queued before `unload_module` fails with a typed
    /// stale-handle error when the executor reaches it.
    ///
    /// `journal` engages the cross-shard atomics protocol (the launch is
    /// a journaled coordinator shard; dropped when the lowered program
    /// performs no global atomics). `memo` is the stream's last
    /// `(module, kernel)` JIT resolution: same-kernel repeat launches
    /// skip the shared cache's lock + key hash entirely. `fault`
    /// (resolved by the event-graph executor from the injector's launch
    /// hook) makes the grid fault deterministically at that block linear
    /// id.
    pub fn run_launch(
        &self,
        device_id: usize,
        spec: &LaunchSpec,
        resume: Option<&[BlockResume]>,
        journal: Option<&AtomicJournal>,
        memo: Option<&Mutex<Option<JitMemo>>>,
        fault: Option<u32>,
    ) -> Result<LaunchOutcome> {
        let dev = self.device(device_id)?;
        // Checked-arithmetic geometry validation up front: overflowing or
        // empty dims surface as a clear runtime error instead of a
        // debug-build panic inside the simulators.
        validate_dims(spec.dims)?;
        let modules = self.modules.read().unwrap();
        let (module, uid) = modules.get(spec.module)?;
        let kernel = module
            .kernel(&spec.kernel)
            .ok_or_else(|| HetError::runtime(format!("no kernel `{}`", spec.kernel)))?;
        let values = args_to_values(kernel, &spec.args)?;

        let tensix_mode = if dev.kind == DeviceKind::TenstorrentSim {
            Some(spec.tensix_mode_hint.unwrap_or_else(|| choose_tensix_mode(kernel, spec.dims)))
        } else {
            None
        };
        let memoized = memo.and_then(|m| {
            let g = m.lock().unwrap();
            g.as_ref().and_then(|mm| mm.lookup(uid, &spec.kernel, dev.kind, tensix_mode))
        });
        let prog = match memoized {
            Some(p) => p,
            None => {
                let key = JitKey {
                    module: uid,
                    kernel: spec.kernel.clone(),
                    kind: dev.kind,
                    tensix_mode,
                    migratable: true,
                };
                let simt_cfg = match &dev.engine {
                    Engine::Simt(s) => Some(s.cfg.clone()),
                    Engine::Tensix(_) => None,
                };
                let p = self.jit.get_or_translate(key, kernel, simt_cfg.as_ref())?;
                if let Some(m) = memo {
                    *m.lock().unwrap() = Some(JitMemo::new(
                        uid,
                        spec.kernel.clone(),
                        dev.kind,
                        tensix_mode,
                        p.clone(),
                    ));
                }
                p
            }
        };
        drop(modules);

        // A program with no global atomics journals nothing — skip the
        // plumbing (the ISA-level classification, threaded through
        // lowering, makes this a static decision).
        let journal = journal.filter(|_| prog.atomics_class() != AtomicsClass::None);

        // Launches take the device gate *shared*: independent launches
        // (different streams, coordinator shards) overlap on one device;
        // only whole-device snapshot capture/restore excludes them.
        let _gate = dev.exec.read().unwrap();
        let out = match (&dev.engine, prog.as_ref()) {
            (Engine::Simt(sim), crate::backends::DeviceProgram::Simt(p)) => sim
                .run_grid_journaled(
                    p,
                    spec.dims,
                    &values,
                    &dev.mem,
                    &dev.pause,
                    resume,
                    journal,
                    fault,
                ),
            (Engine::Tensix(sim), crate::backends::DeviceProgram::Tensix(p)) => {
                // Multi-core shared memory needs a global heap region.
                let heap = if p.mode == TensixMode::VectorMultiCore && p.shared_bytes > 0 {
                    let bytes = p.shared_bytes * spec.dims.grid_size() as u64;
                    Some(self.memory.alloc(bytes, device_id)?)
                } else {
                    None
                };
                let out = sim.run_grid_journaled(
                    p,
                    spec.dims,
                    &values,
                    &dev.mem,
                    &dev.pause,
                    resume,
                    heap.map(|h| h.0),
                    journal,
                    fault,
                );
                if let Some(h) = heap {
                    // Shared contents are captured in block snapshots, so
                    // the heap region can be released either way.
                    let _ = self.memory.free(h);
                }
                out
            }
            _ => Err(HetError::runtime("engine/program kind mismatch (JIT cache corrupt)")),
        };
        // Device faults carry launch provenance: the simulator stamped
        // the faulting block and kernel; the runtime knows the module.
        out.map_err(|e| e.with_fault_kernel(&spec.kernel).with_fault_module(uid))
    }
}
