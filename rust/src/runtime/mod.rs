//! The hetGPU runtime (paper §4.2): device registry, unified memory,
//! JIT translation cache, event-graph streams ([`events`]), kernel launch,
//! generational handle tables (`runtime::handle`), and the execution entry
//! point shared by fresh launches, coordinator shards, and migration
//! resumes.

pub mod api;
pub mod device;
pub mod events;
pub mod faultinject;
pub(crate) mod handle;
pub mod jit;
pub mod launch;
pub mod memory;
pub mod stream;

use crate::delta::journal::AtomicJournal;
use crate::error::{HetError, Result};
use crate::hetir::module::{Kernel, Module};
use crate::isa::tensix_isa::TensixMode;
use crate::isa::AtomicsClass;
use crate::runtime::device::{Device, DeviceKind, Engine};
use crate::runtime::faultinject::FaultInjector;
use crate::runtime::handle::SlotTable;
use crate::runtime::jit::{JitCache, JitKey, JitMemo};
use crate::runtime::launch::{args_to_values, choose_tensix_mode, validate_dims, LaunchSpec};
use crate::runtime::memory::MemoryManager;
use crate::sim::snapshot::{BlockResume, LaunchOutcome};
use std::sync::{Mutex, RwLock};

/// Generational handle to a loaded hetIR module (API v2).
///
/// Minted by `HetGpu::load_module` (and the compile front-ends),
/// invalidated by `HetGpu::unload_module`; stale handles — including
/// launches already queued when the module was unloaded — fail with
/// `HetError::InvalidHandle` instead of silently resolving whichever
/// module reused the slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ModuleHandle {
    pub(crate) slot: u32,
    pub(crate) gen: u32,
}

handle::impl_handle_raw!(ModuleHandle, "module");

/// A loaded module plus the process-unique id the JIT cache keys on and
/// the cached static-analysis report (`None` until the analyzer has run —
/// either eagerly at load, or lazily on the first launch that needs it).
struct LoadedModule {
    module: Module,
    uid: u64,
    /// Content hash of the module's printed hetIR
    /// ([`crate::hetir::printer::module_hash`]) — the address every
    /// AOT/disk-cache artifact of this module is keyed by.
    ir_hash: u128,
    analysis: Option<std::sync::Arc<crate::hetir::analyze::AnalysisReport>>,
}

/// Generational registry of loaded modules.
#[derive(Default)]
pub struct ModuleTable {
    table: SlotTable<LoadedModule>,
    next_uid: u64,
}

impl ModuleTable {
    pub fn new() -> ModuleTable {
        ModuleTable { table: SlotTable::new(), next_uid: 0 }
    }

    pub(crate) fn insert(&mut self, module: Module) -> ModuleHandle {
        let uid = self.next_uid;
        self.next_uid += 1;
        let ir_hash = crate::hetir::printer::module_hash(&module);
        let (slot, gen) = self.table.insert(LoadedModule { module, uid, ir_hash, analysis: None });
        ModuleHandle { slot, gen }
    }

    /// Content hash of a loaded module (the AOT/disk-cache address).
    pub(crate) fn ir_hash(&self, h: ModuleHandle) -> Result<u128> {
        self.table.get(h.slot, h.gen).map(|m| m.ir_hash).ok_or_else(|| {
            HetError::invalid_handle("module", "module was unloaded or never loaded")
        })
    }

    /// Content hash by module **uid** (background compiler path; see
    /// [`ModuleTable::kernel_by_uid`] for why uids, not handles).
    pub(crate) fn ir_hash_by_uid(&self, uid: u64) -> Option<u128> {
        for slot in 0..self.table.slot_count() as u32 {
            if let Some(lm) = self.table.entry_at(slot) {
                if lm.uid == uid {
                    return Some(lm.ir_hash);
                }
            }
        }
        None
    }

    /// The cached analysis report for a module, if the analyzer has run.
    pub(crate) fn analysis(
        &self,
        h: ModuleHandle,
    ) -> Result<Option<std::sync::Arc<crate::hetir::analyze::AnalysisReport>>> {
        self.table
            .get(h.slot, h.gen)
            .map(|m| m.analysis.clone())
            .ok_or_else(|| {
                HetError::invalid_handle("module", "module was unloaded or never loaded")
            })
    }

    /// Cache an analysis report beside the module (idempotent — the
    /// report for a given module never changes, so last write wins).
    pub(crate) fn set_analysis(
        &mut self,
        h: ModuleHandle,
        report: std::sync::Arc<crate::hetir::analyze::AnalysisReport>,
    ) -> Result<()> {
        match self.table.get_mut(h.slot, h.gen) {
            Some(m) => {
                m.analysis = Some(report);
                Ok(())
            }
            None => Err(HetError::invalid_handle(
                "module",
                "module was unloaded or never loaded",
            )),
        }
    }

    /// Resolve a handle → `(module, uid)`; stale handles miss with
    /// [`HetError::InvalidHandle`].
    pub(crate) fn get(&self, h: ModuleHandle) -> Result<(&Module, u64)> {
        self.table
            .get(h.slot, h.gen)
            .map(|m| (&m.module, m.uid))
            .ok_or_else(|| {
                HetError::invalid_handle("module", "module was unloaded or never loaded")
            })
    }

    /// Unload a module; returns its uid for JIT-cache eviction.
    pub(crate) fn remove(&mut self, h: ModuleHandle) -> Result<u64> {
        self.table
            .remove(h.slot, h.gen)
            .map(|m| m.uid)
            .ok_or_else(|| {
                HetError::invalid_handle("module", "module was unloaded or never loaded")
            })
    }

    /// Number of loaded modules.
    pub fn live(&self) -> usize {
        self.table.live()
    }

    /// Resolve a kernel by module **uid** (not handle) — the background
    /// tier-2 compiler holds `JitKey`s, which carry uids. Returns a clone
    /// so the module lock is not held across the compile. `None` when the
    /// module was unloaded while the key sat in the compile queue.
    pub(crate) fn kernel_by_uid(&self, uid: u64, kernel: &str) -> Option<Kernel> {
        for slot in 0..self.table.slot_count() as u32 {
            if let Some(lm) = self.table.entry_at(slot) {
                if lm.uid == uid {
                    return lm.module.kernel(kernel).cloned();
                }
            }
        }
        None
    }
}

/// Shared state behind a [`api::HetGpu`] context.
pub struct RuntimeInner {
    pub devices: Vec<Device>,
    pub modules: RwLock<ModuleTable>,
    pub jit: JitCache,
    pub memory: MemoryManager,
    /// Deterministic fault-injection plane (inert unless a plan is
    /// installed) plus the fault/recovery observability counters.
    pub fault: FaultInjector,
    /// The observability plane (DESIGN.md §13): lifecycle spans, flight
    /// recorder, phase histograms, per-kernel profiles. Disarmed unless
    /// `HETGPU_TRACE` is set or `HetGpu::arm_tracing` ran.
    pub obs: crate::obs::Obs,
}

impl RuntimeInner {
    pub fn device(&self, id: usize) -> Result<&Device> {
        self.devices.get(id).ok_or_else(|| HetError::runtime(format!("no device {id}")))
    }

    /// Execute `spec` on `device_id`, optionally resuming from per-block
    /// directives. This is the single execution path used by streams and
    /// by the migration orchestrator — fresh launch and cross-device
    /// resume differ only in `resume`. The module handle is revalidated
    /// here: a launch queued before `unload_module` fails with a typed
    /// stale-handle error when the executor reaches it.
    ///
    /// `journal` engages the cross-shard atomics protocol (the launch is
    /// a journaled coordinator shard; dropped when the lowered program
    /// performs no global atomics). `memo` is the stream's last
    /// `(module, kernel)` JIT resolution: same-kernel repeat launches
    /// skip the shared cache's lock + key hash entirely — revalidated
    /// against the cache generation so a tier-2 swap is observed at the
    /// next launch boundary. `pinned` bypasses resolution entirely: a
    /// resume of a [`stream::PausedKernel`] must run the exact program
    /// the kernel was suspended under, even if tier 2 swapped in while it
    /// was paused. `fault` (resolved by the event-graph executor from the
    /// injector's launch hook) makes the grid fault deterministically at
    /// that block linear id.
    ///
    /// Returns the outcome **and** the program it ran under, so pause
    /// paths can pin it.
    ///
    /// `parent_span` is the observability parent (the dispatch span of
    /// the executing graph node, or 0): when tracing is armed, a JIT-miss
    /// translation emits a child `translate` span under it, and the
    /// completed launch's cost report folds into the per-kernel profile
    /// table. Disarmed, the whole plane costs one relaxed load.
    #[allow(clippy::too_many_arguments)]
    pub fn run_launch(
        &self,
        device_id: usize,
        spec: &LaunchSpec,
        resume: Option<&[BlockResume]>,
        journal: Option<&AtomicJournal>,
        memo: Option<&Mutex<Option<JitMemo>>>,
        pinned: Option<&std::sync::Arc<crate::backends::DeviceProgram>>,
        fault: Option<u32>,
        parent_span: u64,
    ) -> Result<(LaunchOutcome, std::sync::Arc<crate::backends::DeviceProgram>)> {
        let dev = self.device(device_id)?;
        // Checked-arithmetic geometry validation up front: overflowing or
        // empty dims surface as a clear runtime error instead of a
        // debug-build panic inside the simulators.
        validate_dims(spec.dims)?;
        let modules = self.modules.read().unwrap();
        let (module, uid) = modules.get(spec.module)?;
        let kernel = module
            .kernel(&spec.kernel)
            .ok_or_else(|| HetError::runtime(format!("no kernel `{}`", spec.kernel)))?;
        let values = args_to_values(kernel, &spec.args)?;

        let tensix_mode = if dev.kind == DeviceKind::TenstorrentSim {
            Some(spec.tensix_mode_hint.unwrap_or_else(|| choose_tensix_mode(kernel, spec.dims)))
        } else {
            None
        };
        // The entire tiering cost on an unarmed launch: one relaxed load.
        let gen = self.jit.generation();
        let (prog, profile) = match pinned {
            // Resumes run the suspended kernel's exact translation and
            // don't count toward promotion (they are not fresh launches).
            Some(p) => (p.clone(), None),
            None => {
                let memoized = memo.and_then(|m| {
                    let g = m.lock().unwrap();
                    g.as_ref()
                        .and_then(|mm| mm.lookup(uid, &spec.kernel, dev.kind, tensix_mode, gen))
                });
                match memoized {
                    Some((p, prof)) => {
                        self.jit.count_memo_hit();
                        (p, Some(prof))
                    }
                    None => {
                        let key = JitKey {
                            module: uid,
                            kernel: spec.kernel.clone(),
                            kind: dev.kind,
                            tensix_mode,
                            migratable: true,
                        };
                        let simt_cfg = match &dev.engine {
                            Engine::Simt(s) => Some(s.cfg.clone()),
                            Engine::Tensix(_) => None,
                        };
                        let ir_hash = modules.ir_hash(spec.module).ok();
                        let t_span = self.obs.begin();
                        let res =
                            self.jit.get_or_translate(key, kernel, simt_cfg.as_ref(), ir_hash)?;
                        if let Some(s) = t_span {
                            let tier = match res.tier {
                                crate::backends::JitTier::Baseline => "tier1",
                                crate::backends::JitTier::Optimized => "tier2",
                            };
                            self.obs.end(
                                s,
                                parent_span,
                                crate::obs::Phase::Translate,
                                &format!("{} {tier} {}", spec.kernel, res.source),
                                Some(device_id),
                            );
                        }
                        if let Some(m) = memo {
                            *m.lock().unwrap() = Some(JitMemo::new(
                                uid,
                                spec.kernel.clone(),
                                dev.kind,
                                tensix_mode,
                                &res,
                            ));
                        }
                        (res.prog, Some(res.profile))
                    }
                }
            }
        };
        if let Some(prof) = &profile {
            self.jit.count_launch(prof);
        }
        drop(modules);

        // A program with no global atomics journals nothing — skip the
        // plumbing (the ISA-level classification, threaded through
        // lowering, makes this a static decision).
        let journal = journal.filter(|_| prog.atomics_class() != AtomicsClass::None);

        // Launches take the device gate *shared*: independent launches
        // (different streams, coordinator shards) overlap on one device;
        // only whole-device snapshot capture/restore excludes them.
        let _gate = dev.exec.read().unwrap();
        let out = match (&dev.engine, prog.as_ref()) {
            (Engine::Simt(sim), crate::backends::DeviceProgram::Simt(p)) => sim
                .run_grid_journaled(
                    p,
                    spec.dims,
                    &values,
                    &dev.mem,
                    &dev.pause,
                    resume,
                    journal,
                    fault,
                ),
            (Engine::Tensix(sim), crate::backends::DeviceProgram::Tensix(p)) => {
                // Multi-core shared memory needs a global heap region.
                let heap = if p.mode == TensixMode::VectorMultiCore && p.shared_bytes > 0 {
                    let bytes = p.shared_bytes * spec.dims.grid_size() as u64;
                    Some(self.memory.alloc(bytes, device_id)?)
                } else {
                    None
                };
                let out = sim.run_grid_journaled(
                    p,
                    spec.dims,
                    &values,
                    &dev.mem,
                    &dev.pause,
                    resume,
                    heap.map(|h| h.0),
                    journal,
                    fault,
                );
                if let Some(h) = heap {
                    // Shared contents are captured in block snapshots, so
                    // the heap region can be released either way.
                    let _ = self.memory.free(h);
                }
                out
            }
            _ => Err(HetError::runtime("engine/program kind mismatch (JIT cache corrupt)")),
        };
        // Armed, fold the run's hardware-invariant counters into the
        // per-kernel profile table, attributed to the tier that actually
        // ran (memoized launches bypassed the cache lock, so the tier
        // comes from the cache entry; pinned resumes of an evicted module
        // fall back to baseline).
        if self.obs.armed() {
            if let Ok(o) = &out {
                let tier = self
                    .jit
                    .entry_tier(&JitKey {
                        module: uid,
                        kernel: spec.kernel.clone(),
                        kind: dev.kind,
                        tensix_mode,
                        migratable: true,
                    })
                    .unwrap_or_default();
                self.obs.record_profile(
                    crate::obs::ProfileKey {
                        module: uid,
                        kernel: spec.kernel.clone(),
                        kind: dev.kind,
                        tier,
                    },
                    o.cost(),
                );
            }
        }
        // Device faults carry launch provenance: the simulator stamped
        // the faulting block and kernel; the runtime knows the module.
        out.map(|o| (o, prog))
            .map_err(|e| e.with_fault_kernel(&spec.kernel).with_fault_module(uid))
    }
}

/// Body of the background tier-2 compile thread (spawned by
/// `HetGpu::build`, joined on drop after `JitCache::shutdown_compiler`).
///
/// Blocks on the hot queue; for each hot key it re-resolves the kernel IR
/// by module uid, runs the optimizing mid-end + backend lowering
/// (`JitTier::Optimized`), and installs the swap. Launches never block on
/// this thread: a key whose module vanished, or whose tier-2 lowering
/// fails, is abandoned and the entry stays on tier 1 forever.
pub(crate) fn jit_compiler_loop(inner: std::sync::Arc<RuntimeInner>) {
    use crate::runtime::jit::TranslationSource;
    while let Some(key) = inner.jit.next_hot() {
        // Already at the top tier (an AOT-seeded entry whose launches
        // crossed the hot threshold): nothing to compile.
        if inner.jit.entry_tier(&key) == Some(crate::backends::JitTier::Optimized) {
            inner.jit.abandon_promotion(&key);
            continue;
        }
        let (kernel, ir_hash) = {
            let modules = inner.modules.read().unwrap();
            (modules.kernel_by_uid(key.module, &key.kernel), modules.ir_hash_by_uid(key.module))
        };
        let Some(kernel) = kernel else {
            inner.jit.abandon_promotion(&key);
            continue;
        };
        // Any device of the key's kind carries the needed SIMT config
        // (devices are never removed from a context).
        let simt_cfg = inner.devices.iter().find_map(|d| {
            if d.kind != key.kind {
                return None;
            }
            match &d.engine {
                Engine::Simt(s) => Some(s.cfg.clone()),
                Engine::Tensix(_) => None,
            }
        });
        let t0 = std::time::Instant::now();
        // A prior process may have persisted this exact tier-2 lowering:
        // consult the disk before paying the optimizing mid-end.
        let compiled = match inner.jit.disk_load_tier2(&key, ir_hash) {
            Some(prog) => Ok((prog, TranslationSource::Disk)),
            None => jit::translate_for_key(
                &key,
                &kernel,
                simt_cfg.as_ref(),
                crate::backends::JitTier::Optimized,
            )
            .map(|p| (p, TranslationSource::Fresh)),
        };
        match compiled {
            Ok((prog, source)) => {
                let micros = t0.elapsed().as_secs_f64() * 1e6;
                // Background promotions belong to no launch: a rootless
                // translate span on the runtime track (no-op disarmed).
                inner.obs.span_since(
                    t0,
                    0,
                    crate::obs::Phase::Translate,
                    &format!("{} tier2 (background) {source}", key.kernel),
                    None,
                );
                inner.jit.install_tier2(&key, prog, micros, source, ir_hash);
            }
            Err(_) => inner.jit.abandon_promotion(&key),
        }
    }
}
