//! Generational slot tables — the backing store of every typed runtime
//! handle (API v2).
//!
//! A handle is a `{slot, generation}` pair: the slot indexes a reuse
//! table, the generation says *which* incarnation of the slot the handle
//! was minted for. Destroying a resource frees its slot for reuse and
//! bumps the slot's generation, so any handle minted before the destroy
//! dangles detectably: a lookup with a stale generation misses instead of
//! silently aliasing the resource that reused the slot. This is the
//! CUDA-driver-style lifecycle discipline the paper's §4.3 abstraction
//! layer needs once streams, events, modules and buffers can be destroyed
//! mid-context.
//!
//! The table itself is not synchronized; owners wrap it in their own lock
//! (the event graph's mutex, the memory manager's mutex, the module
//! registry's `RwLock`).

/// Generate the shared `{slot, generation}` handle surface for a handle
/// type with `slot: u32` / `gen: u32` fields: `raw`/`from_raw` packing
/// (slot in the low 32 bits, generation in the high — the form wire
/// blobs carry, so the scheme must stay identical across handle types)
/// and the `label#slot.gen` Display form.
macro_rules! impl_handle_raw {
    ($ty:ident, $label:literal) => {
        impl $ty {
            /// Pack the handle into a single `u64` (slot in the low 32
            /// bits, generation in the high) — the form snapshots and
            /// wire blobs carry.
            pub fn raw(self) -> u64 {
                ((self.gen as u64) << 32) | self.slot as u64
            }

            /// Rebuild a handle from its packed form. The pair is only
            /// meaningful inside the context that minted it: handles
            /// carry no context identity, so a foreign pair usually
            /// misses (stale) but can coincidentally resolve if the
            /// destination context allocated the same slot/generation —
            /// cross-context consumers (snapshot restores) must rebind
            /// handles explicitly rather than trust `from_raw`.
            pub fn from_raw(raw: u64) -> $ty {
                $ty { slot: raw as u32, gen: (raw >> 32) as u32 }
            }
        }

        impl std::fmt::Display for $ty {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                write!(f, concat!($label, "#{}.{}"), self.slot, self.gen)
            }
        }
    };
}
pub(crate) use impl_handle_raw;

/// A generational slot-reuse table.
///
/// Slots are `u32` indices; generations are `u32` counters bumped on each
/// free. Lookups require both to match, so the table distinguishes "never
/// existed", "destroyed", and "slot reused by a newer resource" — all of
/// which surface as a failed lookup.
#[derive(Debug)]
pub(crate) struct SlotTable<T> {
    slots: Vec<Slot<T>>,
    /// Slots available for reuse (LIFO keeps tables dense).
    free: Vec<u32>,
    live: usize,
}

// Hand-written (not derived) so `T` needs no `Default` bound.
impl<T> Default for SlotTable<T> {
    fn default() -> SlotTable<T> {
        SlotTable::new()
    }
}

#[derive(Debug)]
struct Slot<T> {
    /// Current generation; bumped on free so stale handles miss.
    gen: u32,
    entry: Option<T>,
}

impl<T> SlotTable<T> {
    pub fn new() -> SlotTable<T> {
        SlotTable { slots: Vec::new(), free: Vec::new(), live: 0 }
    }

    /// Insert a value, reusing a freed slot when one exists. Returns the
    /// `(slot, generation)` pair to mint the handle from.
    pub fn insert(&mut self, value: T) -> (u32, u32) {
        self.live += 1;
        if let Some(slot) = self.free.pop() {
            let s = &mut self.slots[slot as usize];
            debug_assert!(s.entry.is_none());
            s.entry = Some(value);
            (slot, s.gen)
        } else {
            self.slots.push(Slot { gen: 0, entry: Some(value) });
            ((self.slots.len() - 1) as u32, 0)
        }
    }

    /// Look up a live entry; `None` for never-allocated, destroyed, or
    /// generation-mismatched (slot reused) handles.
    pub fn get(&self, slot: u32, gen: u32) -> Option<&T> {
        self.slots
            .get(slot as usize)
            .filter(|s| s.gen == gen)
            .and_then(|s| s.entry.as_ref())
    }

    /// Mutable variant of [`SlotTable::get`].
    pub fn get_mut(&mut self, slot: u32, gen: u32) -> Option<&mut T> {
        self.slots
            .get_mut(slot as usize)
            .filter(|s| s.gen == gen)
            .and_then(|s| s.entry.as_mut())
    }

    /// Remove the entry behind a handle; bumps the slot generation and
    /// recycles the slot. `None` if the handle is already stale.
    pub fn remove(&mut self, slot: u32, gen: u32) -> Option<T> {
        let s = self.slots.get_mut(slot as usize)?;
        if s.gen != gen || s.entry.is_none() {
            return None;
        }
        let value = s.entry.take();
        s.gen = s.gen.wrapping_add(1);
        self.free.push(slot);
        self.live -= 1;
        value
    }

    /// Remove by slot alone (owner-internal paths that already validated
    /// the handle and only kept the slot).
    pub fn remove_at(&mut self, slot: u32) -> Option<T> {
        let gen = self.slots.get(slot as usize)?.gen;
        self.remove(slot, gen)
    }

    /// Live entry behind `slot`, whatever its generation (owner-internal
    /// iteration).
    pub fn entry_at(&self, slot: u32) -> Option<&T> {
        self.slots.get(slot as usize).and_then(|s| s.entry.as_ref())
    }

    /// Mutable variant of [`SlotTable::entry_at`].
    pub fn entry_at_mut(&mut self, slot: u32) -> Option<&mut T> {
        self.slots.get_mut(slot as usize).and_then(|s| s.entry.as_mut())
    }

    /// Number of live entries.
    pub fn live(&self) -> usize {
        self.live
    }

    /// Number of slots ever allocated (live + reusable). Bounded by the
    /// peak number of concurrently live resources, not total history —
    /// the reclamation property the lifecycle tests assert.
    pub fn slot_count(&self) -> usize {
        self.slots.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slots_are_reused_and_generations_fence_staleness() {
        let mut t: SlotTable<&'static str> = SlotTable::new();
        let (s0, g0) = t.insert("a");
        assert_eq!(t.get(s0, g0), Some(&"a"));
        assert_eq!(t.remove(s0, g0), Some("a"));
        assert_eq!(t.get(s0, g0), None, "destroyed handle must miss");
        assert_eq!(t.remove(s0, g0), None, "double-destroy must miss");

        let (s1, g1) = t.insert("b");
        assert_eq!(s1, s0, "freed slot must be reused");
        assert_ne!(g1, g0, "reused slot must carry a new generation");
        assert_eq!(t.get(s0, g0), None, "stale handle must not alias the reuser");
        assert_eq!(t.get(s1, g1), Some(&"b"));
        assert_eq!(t.live(), 1);
        assert_eq!(t.slot_count(), 1, "history must not grow the table");
    }

    #[test]
    fn churn_stays_bounded_by_peak_liveness() {
        let mut t: SlotTable<u64> = SlotTable::new();
        for i in 0..10_000u64 {
            let (s, g) = t.insert(i);
            assert_eq!(t.remove(s, g), Some(i));
        }
        assert_eq!(t.live(), 0);
        assert_eq!(t.slot_count(), 1, "one-at-a-time churn needs one slot");
    }
}
