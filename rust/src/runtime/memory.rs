//! Unified memory manager: device-independent GPU pointers.
//!
//! Implements paper §4.3 *Memory Allocation*: `gpuMalloc` returns a pointer
//! usable on any GPU through the hetGPU API. We use a **unified virtual
//! address space**: one allocator hands out address ranges, and a buffer's
//! bytes live at the *same* address inside whichever device's DRAM it is
//! currently resident on. Migration therefore copies bytes but never needs
//! to rewrite embedded addresses (the paper's alternative — per-device
//! bases with pointer fix-up — is supported by the snapshot layer via typed
//! pointer registers, and exercised in the migration tests).
//!
//! The allocator is a first-fit free list over the device DRAM range,
//! deterministic across devices by construction.

use crate::error::{HetError, Result};
use std::collections::HashMap;
use std::fmt;
use std::sync::Mutex;

/// A device-independent GPU pointer (a virtual address).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GpuPtr(pub u64);

impl fmt::Display for GpuPtr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "gpu:0x{:x}", self.0)
    }
}

impl GpuPtr {
    /// Pointer arithmetic (byte offset), like CUDA device pointers.
    pub fn offset(self, bytes: u64) -> GpuPtr {
        GpuPtr(self.0 + bytes)
    }
}

#[derive(Debug, Clone)]
struct Alloc {
    addr: u64,
    size: u64,
    /// Device currently holding the bytes.
    device: usize,
}

/// Allocation table + free-list allocator.
pub struct MemoryManager {
    inner: Mutex<Inner>,
}

struct Inner {
    /// Live allocations keyed by base address.
    allocs: HashMap<u64, Alloc>,
    /// Free regions (addr, size), kept sorted by address and coalesced.
    free: Vec<(u64, u64)>,
    capacity: u64,
    bytes_in_use: u64,
}

/// Allocations start above address 0 so that null stays invalid.
const HEAP_BASE: u64 = 4096;

impl MemoryManager {
    pub fn new(capacity: u64) -> MemoryManager {
        MemoryManager {
            inner: Mutex::new(Inner {
                allocs: HashMap::new(),
                free: vec![(HEAP_BASE, capacity - HEAP_BASE)],
                capacity,
                bytes_in_use: 0,
            }),
        }
    }

    /// Allocate `size` bytes resident on `device`.
    pub fn alloc(&self, size: u64, device: usize) -> Result<GpuPtr> {
        if size == 0 {
            return Err(HetError::runtime("zero-size allocation"));
        }
        let size = (size + 255) & !255; // 256-byte granularity
        let mut g = self.inner.lock().unwrap();
        let slot = g
            .free
            .iter()
            .position(|(_, s)| *s >= size)
            .ok_or_else(|| HetError::runtime(format!("out of device memory ({size} bytes)")))?;
        let (addr, fsize) = g.free[slot];
        if fsize == size {
            g.free.remove(slot);
        } else {
            g.free[slot] = (addr + size, fsize - size);
        }
        g.allocs.insert(addr, Alloc { addr, size, device });
        g.bytes_in_use += size;
        Ok(GpuPtr(addr))
    }

    /// Free an allocation (must be the base pointer).
    pub fn free(&self, ptr: GpuPtr) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        let a = g
            .allocs
            .remove(&ptr.0)
            .ok_or_else(|| HetError::runtime(format!("free of unknown pointer {ptr}")))?;
        g.bytes_in_use -= a.size;
        // insert + coalesce
        let pos = g.free.partition_point(|(fa, _)| *fa < a.addr);
        g.free.insert(pos, (a.addr, a.size));
        let mut i = pos.saturating_sub(1);
        while i + 1 < g.free.len() {
            if g.free[i].0 + g.free[i].1 == g.free[i + 1].0 {
                g.free[i].1 += g.free[i + 1].1;
                g.free.remove(i + 1);
            } else {
                i += 1;
            }
        }
        Ok(())
    }

    /// Look up the allocation containing `ptr` → (base, size, device).
    pub fn lookup(&self, ptr: GpuPtr) -> Result<(u64, u64, usize)> {
        let g = self.inner.lock().unwrap();
        // exact base or interior pointer
        for a in g.allocs.values() {
            if ptr.0 >= a.addr && ptr.0 < a.addr + a.size {
                return Ok((a.addr, a.size, a.device));
            }
        }
        Err(HetError::runtime(format!("pointer {ptr} does not name an allocation")))
    }

    /// All live allocations resident on `device` (for migration copies).
    pub fn allocations_on(&self, device: usize) -> Vec<(u64, u64)> {
        let g = self.inner.lock().unwrap();
        let mut v: Vec<(u64, u64)> = g
            .allocs
            .values()
            .filter(|a| a.device == device)
            .map(|a| (a.addr, a.size))
            .collect();
        v.sort_unstable();
        v
    }

    /// Every live allocation → (base, size, resident device), sorted by
    /// address (the coordinator's broadcast/merge set).
    pub fn all_allocations(&self) -> Vec<(u64, u64, usize)> {
        let g = self.inner.lock().unwrap();
        let mut v: Vec<(u64, u64, usize)> =
            g.allocs.values().map(|a| (a.addr, a.size, a.device)).collect();
        v.sort_unstable();
        v
    }

    /// Mark every allocation on `from` as now resident on `to` (after the
    /// migration copy completed).
    pub fn move_residency(&self, from: usize, to: usize) {
        let mut g = self.inner.lock().unwrap();
        for a in g.allocs.values_mut() {
            if a.device == from {
                a.device = to;
            }
        }
    }

    pub fn bytes_in_use(&self) -> u64 {
        self.inner.lock().unwrap().bytes_in_use
    }

    pub fn capacity(&self) -> u64 {
        self.inner.lock().unwrap().capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_reuse() {
        let m = MemoryManager::new(1 << 20);
        let a = m.alloc(1000, 0).unwrap();
        let b = m.alloc(1000, 0).unwrap();
        assert_ne!(a, b);
        m.free(a).unwrap();
        let c = m.alloc(1000, 0).unwrap();
        assert_eq!(a, c, "freed block should be reused first-fit");
        m.free(b).unwrap();
        m.free(c).unwrap();
        assert_eq!(m.bytes_in_use(), 0);
    }

    #[test]
    fn interior_pointer_lookup() {
        let m = MemoryManager::new(1 << 20);
        let a = m.alloc(4096, 2).unwrap();
        let (base, size, dev) = m.lookup(a.offset(100)).unwrap();
        assert_eq!(base, a.0);
        assert_eq!(size, 4096);
        assert_eq!(dev, 2);
        assert!(m.lookup(GpuPtr(0)).is_err());
    }

    #[test]
    fn oom_reported() {
        let m = MemoryManager::new(1 << 16);
        assert!(m.alloc(1 << 20, 0).is_err());
    }

    #[test]
    fn residency_moves() {
        let m = MemoryManager::new(1 << 20);
        let _a = m.alloc(100, 0).unwrap();
        let _b = m.alloc(100, 1).unwrap();
        assert_eq!(m.allocations_on(0).len(), 1);
        m.move_residency(0, 1);
        assert_eq!(m.allocations_on(0).len(), 0);
        assert_eq!(m.allocations_on(1).len(), 2);
    }

    #[test]
    fn coalescing_allows_large_realloc() {
        let m = MemoryManager::new(1 << 20);
        let ptrs: Vec<GpuPtr> = (0..16).map(|_| m.alloc(4096, 0).unwrap()).collect();
        for p in ptrs {
            m.free(p).unwrap();
        }
        // After coalescing, one big allocation must fit again.
        assert!(m.alloc((1 << 20) - 8192, 0).is_ok());
    }
}
