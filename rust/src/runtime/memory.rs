//! Unified memory manager: device-independent GPU pointers and the typed
//! buffer surface of API v2.
//!
//! Implements paper §4.3 *Memory Allocation*: `gpuMalloc` returns a pointer
//! usable on any GPU through the hetGPU API. We use a **unified virtual
//! address space**: one allocator hands out address ranges, and a buffer's
//! bytes live at the *same* address inside whichever device's DRAM it is
//! currently resident on. Migration therefore copies bytes but never needs
//! to rewrite embedded addresses (the paper's alternative — per-device
//! bases with pointer fix-up — is supported by the snapshot layer via typed
//! pointer registers, and exercised in the migration tests).
//!
//! Two surfaces sit on top of the allocator:
//!
//! * the **raw pointer surface** ([`GpuPtr`]): untyped addresses for code
//!   that manages its own layout (the migration machinery, the
//!   coordinator's broadcast/merge set);
//! * the **typed buffer surface** ([`Buffer`]): element-typed,
//!   generation-checked handles used with the generic
//!   `upload`/`download` copies — a stale or freed buffer handle is
//!   rejected with `HetError::InvalidHandle` instead of reading whatever
//!   allocation reused the address range.
//!
//! The allocator is a first-fit free list over the device DRAM range,
//! deterministic across devices by construction.

use crate::error::{HetError, Result};
use crate::runtime::handle::SlotTable;
use std::collections::HashMap;
use std::fmt;
use std::marker::PhantomData;
use std::sync::{Arc, Mutex};

/// A device-independent GPU pointer (a virtual address).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GpuPtr(pub u64);

impl fmt::Display for GpuPtr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "gpu:0x{:x}", self.0)
    }
}

impl GpuPtr {
    /// Pointer arithmetic (byte offset), like CUDA device pointers.
    pub fn offset(self, bytes: u64) -> GpuPtr {
        GpuPtr(self.0 + bytes)
    }
}

/// Element types that can cross the host↔device copy boundary.
///
/// Every implementation round-trips through the device's little-endian
/// byte representation (the layout the simulators, the snapshot blob, and
/// the hetIR value model all share), so uploads and downloads are
/// bit-exact for any payload including NaNs.
pub trait Pod: Copy + Send + Sync + 'static {
    /// Size of one element in device memory, in bytes.
    const SIZE: usize;
    /// Write the little-endian device representation into `out`
    /// (exactly [`Pod::SIZE`] bytes).
    fn write_le(&self, out: &mut [u8]);
    /// Read one element back from its little-endian device representation
    /// (exactly [`Pod::SIZE`] bytes).
    fn read_le(src: &[u8]) -> Self;
}

macro_rules! impl_pod {
    ($($t:ty),* $(,)?) => {
        $(impl Pod for $t {
            const SIZE: usize = std::mem::size_of::<$t>();
            fn write_le(&self, out: &mut [u8]) {
                out.copy_from_slice(&self.to_le_bytes());
            }
            fn read_le(src: &[u8]) -> Self {
                <$t>::from_le_bytes(src.try_into().expect("Pod::SIZE chunk"))
            }
        })*
    };
}

impl_pod!(u8, u16, u32, u64, i8, i16, i32, i64, f32, f64);

/// Serialize a typed slice into its device byte image.
pub(crate) fn pod_to_bytes<T: Pod>(data: &[T]) -> Vec<u8> {
    let mut out = vec![0u8; data.len() * T::SIZE];
    for (chunk, v) in out.chunks_exact_mut(T::SIZE).zip(data) {
        v.write_le(chunk);
    }
    out
}

/// Deserialize a device byte image into typed elements (whole chunks
/// only; callers size `bytes` as a multiple of `T::SIZE`).
pub(crate) fn pod_from_bytes<T: Pod>(bytes: &[u8]) -> Vec<T> {
    bytes.chunks_exact(T::SIZE).map(T::read_le).collect()
}

/// A typed, generation-checked device buffer handle (API v2).
///
/// `Buffer<T>` is `{slot, generation}` over the memory manager's
/// allocation table plus the resolved base pointer and element count. The
/// handle is `Copy` — cheap to pass around — and every copy operation
/// revalidates it, so use-after-free and slot reuse surface as
/// `HetError::InvalidHandle` rather than touching the wrong allocation.
/// Obtain one from `HetGpu::alloc_buffer`, release with
/// `HetGpu::free_buffer`.
#[derive(Debug, Clone, Copy)]
pub struct Buffer<T: Pod> {
    pub(crate) slot: u32,
    pub(crate) gen: u32,
    ptr: GpuPtr,
    len: usize,
    _elem: PhantomData<T>,
}

impl<T: Pod> Buffer<T> {
    pub(crate) fn new(slot: u32, gen: u32, ptr: GpuPtr, len: usize) -> Buffer<T> {
        Buffer { slot, gen, ptr, len, _elem: PhantomData }
    }

    /// The buffer's device address — pass as a kernel pointer argument.
    /// (The address itself is not generation-checked; kernels run against
    /// raw unified memory exactly as on real hardware.)
    pub fn ptr(&self) -> GpuPtr {
        self.ptr
    }

    /// Element capacity.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the buffer holds zero elements (never true for buffers
    /// minted by `alloc_buffer`, which rejects empty allocations).
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Payload size in bytes (`len * T::SIZE`).
    pub fn size_bytes(&self) -> u64 {
        (self.len * T::SIZE) as u64
    }

    /// The buffer as a kernel launch argument (`Arg::Ptr`).
    pub fn arg(&self) -> crate::runtime::launch::Arg {
        crate::runtime::launch::Arg::Ptr(self.ptr)
    }
}

/// A host-side staging buffer for asynchronous device→host copies (the
/// analog of CUDA pinned host memory).
///
/// `memcpy_d2h_async` records a copy node that fills the buffer when the
/// stream reaches it; the handle is clonable (shared contents), and the
/// contents are read back with [`PinnedBuffer::to_vec`] /
/// [`PinnedBuffer::read`] after the copy's event completes.
#[derive(Debug, Clone)]
pub struct PinnedBuffer {
    bytes: Arc<Mutex<Vec<u8>>>,
}

impl PinnedBuffer {
    /// Allocate a zeroed host buffer of `len` bytes.
    pub fn new(len: usize) -> PinnedBuffer {
        PinnedBuffer { bytes: Arc::new(Mutex::new(vec![0u8; len])) }
    }

    /// Capacity in bytes.
    pub fn len(&self) -> usize {
        self.bytes.lock().unwrap().len()
    }

    /// Whether the buffer has zero capacity.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Copy the current contents out.
    pub fn to_vec(&self) -> Vec<u8> {
        self.bytes.lock().unwrap().clone()
    }

    /// Reinterpret the contents as little-endian `T` elements (whole
    /// elements only).
    pub fn read<T: Pod>(&self) -> Vec<T> {
        pod_from_bytes(&self.bytes.lock().unwrap())
    }

    /// Fill the buffer from device bytes (executor-side).
    pub(crate) fn fill_from(
        &self,
        mem: &crate::sim::mem::DeviceMemory,
        addr: u64,
    ) -> Result<()> {
        let mut host = self.bytes.lock().unwrap();
        mem.read_bytes_into(addr, &mut host[..])
    }
}

#[derive(Debug, Clone)]
struct Alloc {
    addr: u64,
    size: u64,
    /// Device currently holding the bytes.
    device: usize,
    /// Slot in the buffer-handle table (freed alongside the allocation).
    slot: u32,
}

/// Allocation table + free-list allocator.
pub struct MemoryManager {
    inner: Mutex<Inner>,
}

struct Inner {
    /// Live allocations keyed by base address.
    allocs: HashMap<u64, Alloc>,
    /// Generational buffer handles → base address (the typed surface's
    /// staleness fence).
    handles: SlotTable<u64>,
    /// Free regions (addr, size), kept sorted by address and coalesced.
    free: Vec<(u64, u64)>,
    capacity: u64,
    bytes_in_use: u64,
}

/// Allocations start above address 0 so that null stays invalid.
const HEAP_BASE: u64 = 4096;

/// Release `ptr`'s allocation and recycle its handle slot; callers hold
/// the manager lock (validation and release must be one critical
/// section).
fn free_locked(g: &mut Inner, ptr: GpuPtr) -> Result<()> {
    let a = g
        .allocs
        .remove(&ptr.0)
        .ok_or_else(|| HetError::runtime(format!("free of unknown pointer {ptr}")))?;
    g.handles.remove_at(a.slot);
    g.bytes_in_use -= a.size;
    // insert + coalesce
    let pos = g.free.partition_point(|(fa, _)| *fa < a.addr);
    g.free.insert(pos, (a.addr, a.size));
    let mut i = pos.saturating_sub(1);
    while i + 1 < g.free.len() {
        if g.free[i].0 + g.free[i].1 == g.free[i + 1].0 {
            g.free[i].1 += g.free[i + 1].1;
            g.free.remove(i + 1);
        } else {
            i += 1;
        }
    }
    Ok(())
}

impl MemoryManager {
    pub fn new(capacity: u64) -> MemoryManager {
        MemoryManager {
            inner: Mutex::new(Inner {
                allocs: HashMap::new(),
                handles: SlotTable::new(),
                free: vec![(HEAP_BASE, capacity - HEAP_BASE)],
                capacity,
                bytes_in_use: 0,
            }),
        }
    }

    /// Allocate `size` bytes resident on `device`, returning the pointer
    /// plus the generational `(slot, generation)` buffer handle.
    pub(crate) fn alloc_handle(&self, size: u64, device: usize) -> Result<(GpuPtr, u32, u32)> {
        if size == 0 {
            return Err(HetError::runtime("zero-size allocation"));
        }
        // 256-byte granularity; checked so sizes near u64::MAX fail
        // closed instead of wrapping to a zero-size allocation that
        // aliases the free list.
        let size = size
            .checked_add(255)
            .ok_or_else(|| HetError::runtime(format!("allocation of {size} bytes too large")))?
            & !255;
        let mut g = self.inner.lock().unwrap();
        let slot_idx = g
            .free
            .iter()
            .position(|(_, s)| *s >= size)
            .ok_or_else(|| HetError::runtime(format!("out of device memory ({size} bytes)")))?;
        let (addr, fsize) = g.free[slot_idx];
        if fsize == size {
            g.free.remove(slot_idx);
        } else {
            g.free[slot_idx] = (addr + size, fsize - size);
        }
        let (slot, gen) = g.handles.insert(addr);
        g.allocs.insert(addr, Alloc { addr, size, device, slot });
        g.bytes_in_use += size;
        Ok((GpuPtr(addr), slot, gen))
    }

    /// Allocate `size` bytes resident on `device` (raw pointer surface).
    pub fn alloc(&self, size: u64, device: usize) -> Result<GpuPtr> {
        self.alloc_handle(size, device).map(|(p, _, _)| p)
    }

    /// Free an allocation (must be the base pointer). Any typed buffer
    /// handle minted for it becomes stale.
    pub fn free(&self, ptr: GpuPtr) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        free_locked(&mut g, ptr)
    }

    /// Free through a typed buffer handle: validation and release happen
    /// under one lock acquisition, so two racing frees of the same
    /// (Copy) handle cannot both pass validation and have the loser free
    /// whatever allocation reused the address range.
    pub(crate) fn free_by_handle(&self, slot: u32, gen: u32) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        let addr = *g
            .handles
            .get(slot, gen)
            .ok_or_else(|| HetError::invalid_handle("buffer", "buffer was freed or never existed"))?;
        free_locked(&mut g, GpuPtr(addr))
    }

    /// Resolve a typed buffer handle → `(base, size, device)`; stale
    /// handles (freed, or their slot reused) miss with
    /// [`HetError::InvalidHandle`].
    pub(crate) fn resolve(&self, slot: u32, gen: u32) -> Result<(u64, u64, usize)> {
        let g = self.inner.lock().unwrap();
        let addr = *g
            .handles
            .get(slot, gen)
            .ok_or_else(|| HetError::invalid_handle("buffer", "buffer was freed or never existed"))?;
        let a = g.allocs.get(&addr).expect("handle table and alloc table in sync");
        Ok((a.addr, a.size, a.device))
    }

    /// Look up the allocation containing `ptr` → (base, size, device).
    pub fn lookup(&self, ptr: GpuPtr) -> Result<(u64, u64, usize)> {
        let g = self.inner.lock().unwrap();
        // exact base or interior pointer
        for a in g.allocs.values() {
            if ptr.0 >= a.addr && ptr.0 < a.addr + a.size {
                return Ok((a.addr, a.size, a.device));
            }
        }
        Err(HetError::runtime(format!("pointer {ptr} does not name an allocation")))
    }

    /// All live allocations resident on `device` (for migration copies).
    pub fn allocations_on(&self, device: usize) -> Vec<(u64, u64)> {
        let g = self.inner.lock().unwrap();
        let mut v: Vec<(u64, u64)> = g
            .allocs
            .values()
            .filter(|a| a.device == device)
            .map(|a| (a.addr, a.size))
            .collect();
        v.sort_unstable();
        v
    }

    /// Every live allocation → (base, size, resident device), sorted by
    /// address (the coordinator's default broadcast/merge set).
    pub fn all_allocations(&self) -> Vec<(u64, u64, usize)> {
        let g = self.inner.lock().unwrap();
        let mut v: Vec<(u64, u64, usize)> =
            g.allocs.values().map(|a| (a.addr, a.size, a.device)).collect();
        v.sort_unstable();
        v
    }

    /// Mark every allocation on `from` as now resident on `to` (after the
    /// migration copy completed).
    pub fn move_residency(&self, from: usize, to: usize) {
        let mut g = self.inner.lock().unwrap();
        for a in g.allocs.values_mut() {
            if a.device == from {
                a.device = to;
            }
        }
    }

    pub fn bytes_in_use(&self) -> u64 {
        self.inner.lock().unwrap().bytes_in_use
    }

    pub fn capacity(&self) -> u64 {
        self.inner.lock().unwrap().capacity
    }

    /// Live typed-buffer handles (lifecycle observability).
    pub fn live_buffers(&self) -> usize {
        self.inner.lock().unwrap().handles.live()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_reuse() {
        let m = MemoryManager::new(1 << 20);
        let a = m.alloc(1000, 0).unwrap();
        let b = m.alloc(1000, 0).unwrap();
        assert_ne!(a, b);
        m.free(a).unwrap();
        let c = m.alloc(1000, 0).unwrap();
        assert_eq!(a, c, "freed block should be reused first-fit");
        m.free(b).unwrap();
        m.free(c).unwrap();
        assert_eq!(m.bytes_in_use(), 0);
        assert_eq!(m.live_buffers(), 0);
    }

    #[test]
    fn interior_pointer_lookup() {
        let m = MemoryManager::new(1 << 20);
        let a = m.alloc(4096, 2).unwrap();
        let (base, size, dev) = m.lookup(a.offset(100)).unwrap();
        assert_eq!(base, a.0);
        assert_eq!(size, 4096);
        assert_eq!(dev, 2);
        assert!(m.lookup(GpuPtr(0)).is_err());
    }

    #[test]
    fn oom_reported() {
        let m = MemoryManager::new(1 << 16);
        assert!(m.alloc(1 << 20, 0).is_err());
        // Sizes whose 256-byte rounding would wrap u64 fail closed
        // instead of minting a zero-size aliasing allocation.
        assert!(m.alloc(u64::MAX, 0).is_err());
        assert!(m.alloc(u64::MAX - 100, 0).is_err());
    }

    #[test]
    fn residency_moves() {
        let m = MemoryManager::new(1 << 20);
        let _a = m.alloc(100, 0).unwrap();
        let _b = m.alloc(100, 1).unwrap();
        assert_eq!(m.allocations_on(0).len(), 1);
        m.move_residency(0, 1);
        assert_eq!(m.allocations_on(0).len(), 0);
        assert_eq!(m.allocations_on(1).len(), 2);
    }

    #[test]
    fn coalescing_allows_large_realloc() {
        let m = MemoryManager::new(1 << 20);
        let ptrs: Vec<GpuPtr> = (0..16).map(|_| m.alloc(4096, 0).unwrap()).collect();
        for p in ptrs {
            m.free(p).unwrap();
        }
        // After coalescing, one big allocation must fit again.
        assert!(m.alloc((1 << 20) - 8192, 0).is_ok());
    }

    #[test]
    fn buffer_handles_go_stale_on_free_and_reuse() {
        let m = MemoryManager::new(1 << 20);
        let (p1, s1, g1) = m.alloc_handle(512, 0).unwrap();
        assert_eq!(m.resolve(s1, g1).unwrap().0, p1.0);
        m.free(p1).unwrap();
        let e = m.resolve(s1, g1).unwrap_err();
        assert!(e.is_invalid_handle(), "{e}");
        // The same address and slot get reused — the old handle must not
        // alias the new allocation.
        let (p2, s2, g2) = m.alloc_handle(512, 0).unwrap();
        assert_eq!(p1, p2);
        assert_eq!(s1, s2);
        assert_ne!(g1, g2);
        assert!(m.resolve(s1, g1).is_err());
        assert!(m.resolve(s2, g2).is_ok());
    }

    #[test]
    fn pod_roundtrip_bit_exact() {
        let data = [f32::NAN, -0.0, 1.5, f32::INFINITY];
        let bytes = pod_to_bytes(&data);
        let back: Vec<f32> = pod_from_bytes(&bytes);
        for (a, b) in data.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let ints = [u32::MAX, 0, 7];
        assert_eq!(pod_from_bytes::<u32>(&pod_to_bytes(&ints)), ints);
    }
}
