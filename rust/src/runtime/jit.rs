//! JIT translation cache (paper §4.2 Module Loading and JIT: "the runtime
//! caches these translated kernels, so repeated launches don't incur
//! translation overhead").
//!
//! Also records per-translation timing — the data behind the paper's §6.2
//! "Translation/JIT cost" table (bench E4).

use crate::backends::{self, DeviceProgram, TranslateOpts};
use crate::error::Result;
use crate::hetir::module::Kernel;
use crate::isa::simt_isa::SimtConfig;
use crate::isa::tensix_isa::TensixMode;
use crate::runtime::device::DeviceKind;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Cache key: one translation per (module, kernel, target, mode, build).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct JitKey {
    pub module: usize,
    pub kernel: String,
    pub kind: DeviceKind,
    pub tensix_mode: Option<TensixMode>,
    pub migratable: bool,
}

/// One recorded translation event (for the E4 table).
#[derive(Debug, Clone)]
pub struct JitEvent {
    pub kernel: String,
    pub kind: DeviceKind,
    pub tensix_mode: Option<TensixMode>,
    pub micros: f64,
    pub out_insts: usize,
}

#[derive(Default)]
pub struct JitCache {
    map: Mutex<HashMap<JitKey, Arc<DeviceProgram>>>,
    events: Mutex<Vec<JitEvent>>,
    hits: Mutex<u64>,
}

impl JitCache {
    pub fn new() -> JitCache {
        JitCache::default()
    }

    /// Translate (or fetch the cached translation of) `kernel` for the
    /// target identified by `key`. `simt_cfg` must be provided for SIMT
    /// targets.
    pub fn get_or_translate(
        &self,
        key: JitKey,
        kernel: &Kernel,
        simt_cfg: Option<&SimtConfig>,
    ) -> Result<Arc<DeviceProgram>> {
        if let Some(p) = self.map.lock().unwrap().get(&key) {
            *self.hits.lock().unwrap() += 1;
            return Ok(p.clone());
        }
        let opts = TranslateOpts { migratable: key.migratable };
        let t0 = Instant::now();
        let prog = match key.kind {
            DeviceKind::TenstorrentSim => {
                let mode = key.tensix_mode.expect("tensix mode required");
                DeviceProgram::Tensix(backends::translate_tensix(kernel, mode, opts)?)
            }
            _ => {
                let cfg = simt_cfg.expect("simt config required");
                DeviceProgram::Simt(backends::translate_simt(kernel, cfg, opts)?)
            }
        };
        let micros = t0.elapsed().as_secs_f64() * 1e6;
        self.events.lock().unwrap().push(JitEvent {
            kernel: key.kernel.clone(),
            kind: key.kind,
            tensix_mode: key.tensix_mode,
            micros,
            out_insts: prog.inst_count(),
        });
        let prog = Arc::new(prog);
        self.map.lock().unwrap().insert(key, prog.clone());
        Ok(prog)
    }

    /// Recorded translation events (E4 table data).
    pub fn events(&self) -> Vec<JitEvent> {
        self.events.lock().unwrap().clone()
    }

    /// Cache hit count (repeated-launch check, §6.2 "0.11 ms on
    /// subsequent runs (cached)").
    pub fn hit_count(&self) -> u64 {
        *self.hits.lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetir::builder::KernelBuilder;
    use crate::hetir::types::Type;

    fn tiny_kernel() -> Kernel {
        let mut b = KernelBuilder::new("k");
        let _p = b.param("p", Type::PTR_GLOBAL);
        b.finish()
    }

    #[test]
    fn caches_by_key() {
        let cache = JitCache::new();
        let k = tiny_kernel();
        let key = JitKey {
            module: 0,
            kernel: "k".into(),
            kind: DeviceKind::NvidiaSim,
            tensix_mode: None,
            migratable: true,
        };
        let cfg = SimtConfig::nvidia();
        let a = cache.get_or_translate(key.clone(), &k, Some(&cfg)).unwrap();
        let b = cache.get_or_translate(key, &k, Some(&cfg)).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.hit_count(), 1);
        assert_eq!(cache.events().len(), 1);
    }

    #[test]
    fn different_targets_translate_separately() {
        let cache = JitCache::new();
        let k = tiny_kernel();
        let cfg = SimtConfig::nvidia();
        let mk = |kind, mode| JitKey {
            module: 0,
            kernel: "k".into(),
            kind,
            tensix_mode: mode,
            migratable: true,
        };
        cache.get_or_translate(mk(DeviceKind::NvidiaSim, None), &k, Some(&cfg)).unwrap();
        cache
            .get_or_translate(
                mk(DeviceKind::TenstorrentSim, Some(TensixMode::VectorSingleCore)),
                &k,
                None,
            )
            .unwrap();
        assert_eq!(cache.events().len(), 2);
        assert_eq!(cache.hit_count(), 0);
    }
}
