//! JIT translation cache (paper §4.2 Module Loading and JIT: "the runtime
//! caches these translated kernels, so repeated launches don't incur
//! translation overhead").
//!
//! Also records per-translation timing — the data behind the paper's §6.2
//! "Translation/JIT cost" table (bench E4).

use crate::backends::{self, DeviceProgram, TranslateOpts};
use crate::error::Result;
use crate::hetir::module::Kernel;
use crate::isa::simt_isa::SimtConfig;
use crate::isa::tensix_isa::TensixMode;
use crate::runtime::device::DeviceKind;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Cache key: one translation per (module, kernel, target, mode, build).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct JitKey {
    /// The loaded module's unique id (`ModuleTable` uid, not its slot):
    /// module slots are reused after `unload_module`, so keying by slot
    /// would let a stale translation alias a newly loaded module.
    pub module: u64,
    pub kernel: String,
    pub kind: DeviceKind,
    pub tensix_mode: Option<TensixMode>,
    pub migratable: bool,
}

/// One stream's memo of its most recent `(module, kernel)` JIT
/// resolution — the first rung of launch batching. Back-to-back launches
/// of the same kernel on one stream are the dominant pattern for
/// sub-millisecond kernels, where the E4 cost table shows the *lookup*
/// (shared-cache mutex + key hash, including a `String` clone per
/// launch) dominating; the memo turns the repeat case into four integer/
/// enum compares and one string compare, with no shared-lock traffic.
///
/// Module identity is the `ModuleTable` **uid**, which is unique per
/// load and never reused — a memo held across `unload_module` can never
/// alias a reloaded module; it simply stops matching.
pub struct JitMemo {
    module_uid: u64,
    kernel: String,
    kind: DeviceKind,
    tensix_mode: Option<TensixMode>,
    prog: Arc<DeviceProgram>,
}

impl JitMemo {
    pub fn new(
        module_uid: u64,
        kernel: String,
        kind: DeviceKind,
        tensix_mode: Option<TensixMode>,
        prog: Arc<DeviceProgram>,
    ) -> JitMemo {
        JitMemo { module_uid, kernel, kind, tensix_mode, prog }
    }

    /// The memoized program when it matches this resolution request
    /// (migratable builds only — the launch path always translates with
    /// migration support).
    pub fn lookup(
        &self,
        module_uid: u64,
        kernel: &str,
        kind: DeviceKind,
        tensix_mode: Option<TensixMode>,
    ) -> Option<Arc<DeviceProgram>> {
        (self.module_uid == module_uid
            && self.kind == kind
            && self.tensix_mode == tensix_mode
            && self.kernel == kernel)
            .then(|| self.prog.clone())
    }
}

/// One recorded translation event (for the E4 table).
#[derive(Debug, Clone)]
pub struct JitEvent {
    pub kernel: String,
    pub kind: DeviceKind,
    pub tensix_mode: Option<TensixMode>,
    pub micros: f64,
    pub out_insts: usize,
}

/// All mutable cache state behind one lock: the map, the E4 event log, and
/// the hit counter move together, so a cache decision and its accounting
/// are a single critical section (three separate mutexes previously let
/// concurrent launches interleave them inconsistently).
#[derive(Default)]
struct JitState {
    map: HashMap<JitKey, Arc<DeviceProgram>>,
    events: Vec<JitEvent>,
    hits: u64,
}

#[derive(Default)]
pub struct JitCache {
    state: Mutex<JitState>,
}

impl JitCache {
    pub fn new() -> JitCache {
        JitCache::default()
    }

    /// Translate (or fetch the cached translation of) `kernel` for the
    /// target identified by `key`. `simt_cfg` must be provided for SIMT
    /// targets.
    ///
    /// The lock is **not** held across translation, so a slow translation
    /// can't stall unrelated launches. Concurrent misses on the same key
    /// may translate redundantly; the first to publish wins, later threads
    /// discard their duplicate and count a hit — exactly one `JitEvent`
    /// per distinct key, and every caller sees the same `Arc`.
    pub fn get_or_translate(
        &self,
        key: JitKey,
        kernel: &Kernel,
        simt_cfg: Option<&SimtConfig>,
    ) -> Result<Arc<DeviceProgram>> {
        {
            let mut st = self.state.lock().unwrap();
            if let Some(p) = st.map.get(&key) {
                let p = p.clone();
                st.hits += 1;
                return Ok(p);
            }
        }

        let opts = TranslateOpts { migratable: key.migratable };
        let t0 = Instant::now();
        let prog = match key.kind {
            DeviceKind::TenstorrentSim => {
                let mode = key.tensix_mode.expect("tensix mode required");
                DeviceProgram::Tensix(backends::translate_tensix(kernel, mode, opts)?)
            }
            _ => {
                let cfg = simt_cfg.expect("simt config required");
                DeviceProgram::Simt(backends::translate_simt(kernel, cfg, opts)?)
            }
        };
        let micros = t0.elapsed().as_secs_f64() * 1e6;

        let mut st = self.state.lock().unwrap();
        if let Some(p) = st.map.get(&key) {
            // Lost the miss race: keep the published program.
            let p = p.clone();
            st.hits += 1;
            return Ok(p);
        }
        st.events.push(JitEvent {
            kernel: key.kernel.clone(),
            kind: key.kind,
            tensix_mode: key.tensix_mode,
            micros,
            out_insts: prog.inst_count(),
        });
        let prog = Arc::new(prog);
        st.map.insert(key, prog.clone());
        Ok(prog)
    }

    /// Drop every cached translation of `module` (called by
    /// `unload_module` so unloading actually releases the translated
    /// programs, not just the IR).
    pub fn evict_module(&self, module: u64) {
        let mut st = self.state.lock().unwrap();
        st.map.retain(|k, _| k.module != module);
    }

    /// Recorded translation events (E4 table data).
    pub fn events(&self) -> Vec<JitEvent> {
        self.state.lock().unwrap().events.clone()
    }

    /// Cache hit count (repeated-launch check, §6.2 "0.11 ms on
    /// subsequent runs (cached)").
    pub fn hit_count(&self) -> u64 {
        self.state.lock().unwrap().hits
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetir::builder::KernelBuilder;
    use crate::hetir::types::Type;

    fn tiny_kernel() -> Kernel {
        let mut b = KernelBuilder::new("k");
        let _p = b.param("p", Type::PTR_GLOBAL);
        b.finish()
    }

    #[test]
    fn caches_by_key() {
        let cache = JitCache::new();
        let k = tiny_kernel();
        let key = JitKey {
            module: 0,
            kernel: "k".into(),
            kind: DeviceKind::NvidiaSim,
            tensix_mode: None,
            migratable: true,
        };
        let cfg = SimtConfig::nvidia();
        let a = cache.get_or_translate(key.clone(), &k, Some(&cfg)).unwrap();
        let b = cache.get_or_translate(key, &k, Some(&cfg)).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.hit_count(), 1);
        assert_eq!(cache.events().len(), 1);
    }

    #[test]
    fn concurrent_misses_record_one_event_and_share_one_program() {
        let cache = JitCache::new();
        let k = tiny_kernel();
        let cfg = SimtConfig::nvidia();
        let key = JitKey {
            module: 0,
            kernel: "k".into(),
            kind: DeviceKind::NvidiaSim,
            tensix_mode: None,
            migratable: true,
        };
        let progs: Vec<Arc<DeviceProgram>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    s.spawn(|| {
                        cache.get_or_translate(key.clone(), &k, Some(&cfg)).unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(cache.events().len(), 1, "duplicate JitEvents recorded");
        for p in &progs[1..] {
            assert!(Arc::ptr_eq(&progs[0], p), "threads saw different programs");
        }
        // Exactly one miss translated-and-published; the other 7 hit
        // (either before translating or when they lost the publish race).
        assert_eq!(cache.hit_count(), 7);
    }

    #[test]
    fn different_targets_translate_separately() {
        let cache = JitCache::new();
        let k = tiny_kernel();
        let cfg = SimtConfig::nvidia();
        let mk = |kind, mode| JitKey {
            module: 0,
            kernel: "k".into(),
            kind,
            tensix_mode: mode,
            migratable: true,
        };
        cache.get_or_translate(mk(DeviceKind::NvidiaSim, None), &k, Some(&cfg)).unwrap();
        cache
            .get_or_translate(
                mk(DeviceKind::TenstorrentSim, Some(TensixMode::VectorSingleCore)),
                &k,
                None,
            )
            .unwrap();
        assert_eq!(cache.events().len(), 2);
        assert_eq!(cache.hit_count(), 0);
    }
}
