//! JIT translation cache (paper §4.2 Module Loading and JIT: "the runtime
//! caches these translated kernels, so repeated launches don't incur
//! translation overhead") — now two-tiered (DESIGN.md §11).
//!
//! **Tier 1** is the fast first-launch translate, unchanged. Every launch
//! bumps the cache entry's hit profile; when a `(module uid, kernel, kind,
//! mode)` pair crosses [`TierPolicy::hot_threshold`] launches, the key is
//! queued for the background compile thread (owned by `HetGpu`, see
//! `runtime::jit_compiler_loop`), which re-lowers the kernel through the
//! optimizing **tier-2** hetIR mid-end (`hetir::passes::optimize_tier2`)
//! and [`JitCache::install_tier2`]s the result. The swap is an `Arc`
//! replacement under the cache lock plus a generation bump — running grids
//! keep their pinned tier-1 `Arc`; the *next* launch boundary observes
//! tier 2. Per-stream [`JitMemo`]s revalidate against the generation
//! counter (one relaxed atomic load on the launch path), so a memo can
//! never pin a stale tier-1 translation alive.
//!
//! Both tiers are bit-identical in everything the determinism suite
//! measures (memory, cost reports, snapshot blobs); tier 2 only shrinks
//! host-side simulation work. See `optimize_tier2` for why.
//!
//! Also records per-translation timing — the data behind the paper's §6.2
//! "Translation/JIT cost" table (bench E4) — in a bounded ring (aggregate
//! counters stay exact; see [`JitStats`]).

use crate::aot::diskcache::{CacheKey, CacheStats, DiskCache};
use crate::backends::{self, DeviceProgram, JitTier, TranslateOpts};
use crate::error::Result;
use crate::hetir::module::Kernel;
use crate::isa::simt_isa::SimtConfig;
use crate::isa::tensix_isa::TensixMode;
use crate::runtime::device::DeviceKind;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Default launch count after which an entry is promoted to tier 2.
pub const DEFAULT_HOT_THRESHOLD: u64 = 32;

/// Translation events kept for the E4 table; older events are dropped
/// (counted in [`JitStats::events_dropped`]) so long-lived serving runs
/// don't grow without bound.
const EVENT_RING_CAP: usize = 512;

/// Tiering policy: when to promote, and the forced-tier debug override.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TierPolicy {
    /// Launches of one cache entry before it is queued for tier 2.
    pub hot_threshold: u64,
    /// `Some(tier)` pins every translation to that tier: `Baseline`
    /// disables promotion entirely, `Optimized` compiles tier 2 eagerly
    /// on first launch (no background thread involved). `None` = adaptive.
    pub force: Option<JitTier>,
}

impl Default for TierPolicy {
    fn default() -> Self {
        TierPolicy { hot_threshold: DEFAULT_HOT_THRESHOLD, force: None }
    }
}

/// Parse `HETGPU_JIT_HOT_THRESHOLD`. `0` is clamped to 1 (promote after
/// the first launch), not an error. Returns the value plus the warning to
/// print for malformed input.
fn parse_hot_threshold(raw: &str) -> (u64, Option<String>) {
    match raw.trim().parse::<u64>() {
        Ok(0) => (1, None),
        Ok(n) => (n, None),
        Err(_) => (
            DEFAULT_HOT_THRESHOLD,
            Some(format!(
                "hetgpu: HETGPU_JIT_HOT_THRESHOLD={raw:?} is not a number; \
                 falling back to the default of {DEFAULT_HOT_THRESHOLD} launches"
            )),
        ),
    }
}

/// Parse `HETGPU_JIT_TIER` (`1` = force baseline, `2` = force optimized).
/// Returns the override plus the warning to print for malformed input.
fn parse_forced_tier(raw: &str) -> (Option<JitTier>, Option<String>) {
    match raw.trim() {
        "1" => (Some(JitTier::Baseline), None),
        "2" => (Some(JitTier::Optimized), None),
        _ => (
            None,
            Some(format!(
                "hetgpu: HETGPU_JIT_TIER={raw:?} is not a tier (expected 1 or 2); \
                 leaving tiering adaptive"
            )),
        ),
    }
}

impl TierPolicy {
    /// Policy from `HETGPU_JIT_HOT_THRESHOLD` / `HETGPU_JIT_TIER`.
    /// Malformed values warn loudly once per process, naming the bad value
    /// and the default used — the `HETGPU_SIM_THREADS` contract.
    pub fn from_env() -> TierPolicy {
        let mut p = TierPolicy::default();
        if let Ok(raw) = std::env::var("HETGPU_JIT_HOT_THRESHOLD") {
            let (v, warn) = parse_hot_threshold(&raw);
            p.hot_threshold = v;
            if let Some(msg) = warn {
                crate::hetir::analyze::warn_once(&msg);
            }
        }
        if let Ok(raw) = std::env::var("HETGPU_JIT_TIER") {
            let (f, warn) = parse_forced_tier(&raw);
            p.force = f;
            if let Some(msg) = warn {
                crate::hetir::analyze::warn_once(&msg);
            }
        }
        p
    }
}

/// Where a cached program's bits came from (DESIGN.md §14) — threaded
/// into Translate spans (`aot | disk-hit | fresh`) and the E4 cost
/// table, so warm-start claims are measurable, not vibes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TranslationSource {
    /// Seeded from a fat-blob artifact at `load_fat_blob` time.
    Aot,
    /// Loaded from the on-disk translation cache (a prior process — or
    /// an earlier context in this one — paid the lowering).
    Disk,
    /// Lowered from hetIR in this process.
    #[default]
    Fresh,
}

impl std::fmt::Display for TranslationSource {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            TranslationSource::Aot => "aot",
            TranslationSource::Disk => "disk-hit",
            TranslationSource::Fresh => "fresh",
        })
    }
}

/// Cache key: one translation per (module, kernel, target, mode, build).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct JitKey {
    /// The loaded module's unique id (`ModuleTable` uid, not its slot):
    /// module slots are reused after `unload_module`, so keying by slot
    /// would let a stale translation alias a newly loaded module.
    pub module: u64,
    pub kernel: String,
    pub kind: DeviceKind,
    pub tensix_mode: Option<TensixMode>,
    pub migratable: bool,
}

/// The launch-count profile of one cache entry. Shared (`Arc`) between
/// the cache entry and every stream memo of it, so memoized repeat
/// launches — which never touch the cache lock — still count toward
/// promotion: one relaxed `fetch_add` per launch.
pub struct EntryProfile {
    key: JitKey,
    launches: AtomicU64,
}

impl EntryProfile {
    /// Launches counted against this entry so far.
    pub fn launches(&self) -> u64 {
        self.launches.load(Ordering::Relaxed)
    }
}

/// A cache resolution: the program plus the profile to count launches
/// against and the generation the resolution was made at (memos store it;
/// a later swap bumps the generation and invalidates them).
pub struct JitResolution {
    pub prog: Arc<DeviceProgram>,
    pub profile: Arc<EntryProfile>,
    pub gen: u64,
    /// The tier of the resolved program (the observability plane labels
    /// translate spans and profile keys with it).
    pub tier: JitTier,
    /// Where the resolved program's bits originated (cache hits report
    /// the installed entry's provenance, not the lookup path).
    pub source: TranslationSource,
}

/// One stream's memo of its most recent `(module, kernel)` JIT
/// resolution — the first rung of launch batching. Back-to-back launches
/// of the same kernel on one stream are the dominant pattern for
/// sub-millisecond kernels, where the E4 cost table shows the *lookup*
/// (shared-cache mutex + key hash, including a `String` clone per
/// launch) dominating; the memo turns the repeat case into four integer/
/// enum compares, one string compare, and one relaxed generation load,
/// with no shared-lock traffic.
///
/// Module identity is the `ModuleTable` **uid**, which is unique per
/// load and never reused — a memo held across `unload_module` can never
/// alias a reloaded module; it simply stops matching. Tier swaps are
/// observed through the generation: [`JitCache::install_tier2`] bumps it,
/// the next `lookup` mismatches, and the launch re-resolves through the
/// cache (re-memoizing the tier-2 program at the new generation).
pub struct JitMemo {
    module_uid: u64,
    kernel: String,
    kind: DeviceKind,
    tensix_mode: Option<TensixMode>,
    gen: u64,
    prog: Arc<DeviceProgram>,
    profile: Arc<EntryProfile>,
}

impl JitMemo {
    pub fn new(
        module_uid: u64,
        kernel: String,
        kind: DeviceKind,
        tensix_mode: Option<TensixMode>,
        res: &JitResolution,
    ) -> JitMemo {
        JitMemo {
            module_uid,
            kernel,
            kind,
            tensix_mode,
            gen: res.gen,
            prog: res.prog.clone(),
            profile: res.profile.clone(),
        }
    }

    /// The memoized program when it matches this resolution request AND
    /// the cache generation it was taken at (migratable builds only — the
    /// launch path always translates with migration support). Pass the
    /// current [`JitCache::generation`]: any swap since memoization forces
    /// a cache re-resolution.
    pub fn lookup(
        &self,
        module_uid: u64,
        kernel: &str,
        kind: DeviceKind,
        tensix_mode: Option<TensixMode>,
        gen: u64,
    ) -> Option<(Arc<DeviceProgram>, Arc<EntryProfile>)> {
        (self.gen == gen
            && self.module_uid == module_uid
            && self.kind == kind
            && self.tensix_mode == tensix_mode
            && self.kernel == kernel)
            .then(|| (self.prog.clone(), self.profile.clone()))
    }
}

/// One recorded translation event (for the E4 table).
#[derive(Debug, Clone)]
pub struct JitEvent {
    pub kernel: String,
    pub kind: DeviceKind,
    pub tensix_mode: Option<TensixMode>,
    pub tier: JitTier,
    /// Fresh events time the lowering; disk-hit events time the load.
    pub micros: f64,
    pub out_insts: usize,
    pub source: TranslationSource,
}

/// Aggregate JIT observability (`HetGpu::jit_stats`). The counters are
/// exact for the life of the process; only the per-event ring is bounded.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct JitStats {
    /// Cache-lock hits (memoized repeat launches don't count here).
    pub hits: u64,
    /// Per-stream memo fast-path hits: repeat launches that skipped the
    /// shared cache lock entirely. Split out from `hits` (and from the
    /// translation counters — memo revalidation used to be
    /// indistinguishable from cold work) so the E4 tiers are measurable.
    pub memo_hits: u64,
    /// Misses satisfied from the on-disk translation cache — zero
    /// lowering work, one file read + decode.
    pub disk_hits: u64,
    /// Entries installed from a fat-blob artifact at load time.
    pub aot_seeded: u64,
    /// Tier-1 (baseline) translations performed. **Fresh lowerings
    /// only** — disk hits count in [`JitStats::disk_hits`].
    pub tier1_translations: u64,
    /// Tier-2 (optimized) translations performed — background promotions
    /// plus forced-tier-2 eager translations. Fresh lowerings only.
    pub tier2_translations: u64,
    /// Entries promoted tier 1 → tier 2 by the background compiler.
    pub promotions: u64,
    /// Hot keys queued or compiling right now.
    pub in_flight_compiles: u64,
    /// Program swaps installed at a launch boundary.
    pub swaps: u64,
    /// Current cache generation (bumped once per swap).
    pub generation: u64,
    /// `JitEvent`s dropped from the bounded ring.
    pub events_dropped: u64,
}

/// One cached translation plus its tier, provenance, and launch profile.
struct Entry {
    prog: Arc<DeviceProgram>,
    tier: JitTier,
    profile: Arc<EntryProfile>,
    source: TranslationSource,
}

/// All mutable cache state behind one lock: the map, the E4 event ring,
/// and the counters move together, so a cache decision and its accounting
/// are a single critical section.
#[derive(Default)]
struct JitState {
    map: HashMap<JitKey, Entry>,
    events: VecDeque<JitEvent>,
    hits: u64,
    disk_hits: u64,
    aot_seeded: u64,
    tier1_translations: u64,
    tier2_translations: u64,
    promotions: u64,
    swaps: u64,
    events_dropped: u64,
}

impl JitState {
    fn push_event(&mut self, cap: usize, ev: JitEvent) {
        if self.events.len() >= cap {
            self.events.pop_front();
            self.events_dropped += 1;
        }
        self.events.push_back(ev);
    }
}

/// Hot keys awaiting the background compiler.
#[derive(Default)]
struct CompileQueue {
    pending: VecDeque<JitKey>,
    shutdown: bool,
}

pub struct JitCache {
    state: Mutex<JitState>,
    queue: Mutex<CompileQueue>,
    queue_cond: Condvar,
    /// Bumped (release) once per installed swap; the launch path reads it
    /// relaxed to revalidate stream memos. Monotonic, never reset.
    generation: AtomicU64,
    in_flight: AtomicU64,
    /// Memo fast-path hits: counted outside the state lock (the whole
    /// point of the memo is not taking it), folded into [`JitStats`].
    memo_hits: AtomicU64,
    /// On-disk translation cache (DESIGN.md §14), `None` when disabled.
    /// Consulted on misses before lowering; fresh results persist into it.
    disk: Option<DiskCache>,
    policy: TierPolicy,
    event_cap: usize,
}

impl Default for JitCache {
    fn default() -> Self {
        JitCache::with_policy(TierPolicy::default())
    }
}

impl JitCache {
    pub fn new() -> JitCache {
        JitCache::default()
    }

    pub fn with_policy(policy: TierPolicy) -> JitCache {
        JitCache::with_policy_and_disk(policy, None)
    }

    pub fn with_policy_and_disk(policy: TierPolicy, disk: Option<DiskCache>) -> JitCache {
        JitCache {
            state: Mutex::default(),
            queue: Mutex::default(),
            queue_cond: Condvar::new(),
            generation: AtomicU64::new(0),
            in_flight: AtomicU64::new(0),
            memo_hits: AtomicU64::new(0),
            disk,
            policy,
            event_cap: EVENT_RING_CAP,
        }
    }

    /// The active tiering policy.
    pub fn policy(&self) -> TierPolicy {
        self.policy
    }

    /// Current cache generation — one relaxed load; this is the entire
    /// launch-path cost of tiering when nothing is hot (the faultinject
    /// gate discipline).
    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Relaxed)
    }

    /// Translate (or fetch the cached translation of) `kernel` for the
    /// target identified by `key`. `simt_cfg` must be provided for SIMT
    /// targets. `ir_hash` is the owning module's content hash; with it and
    /// a configured disk cache, misses consult the disk before lowering
    /// and fresh lowerings persist for the next process.
    ///
    /// The lock is **not** held across translation, so a slow translation
    /// can't stall unrelated launches. Concurrent misses on the same key
    /// may translate redundantly; the first to publish wins, later threads
    /// discard their duplicate and count a hit — exactly one `JitEvent`
    /// per distinct key, and every caller sees the same `Arc`.
    pub fn get_or_translate(
        &self,
        key: JitKey,
        kernel: &Kernel,
        simt_cfg: Option<&SimtConfig>,
        ir_hash: Option<u128>,
    ) -> Result<JitResolution> {
        {
            let mut st = self.state.lock().unwrap();
            if let Some(e) = st.map.get(&key) {
                let res = JitResolution {
                    prog: e.prog.clone(),
                    profile: e.profile.clone(),
                    gen: self.generation(),
                    tier: e.tier,
                    source: e.source,
                };
                st.hits += 1;
                return Ok(res);
            }
        }

        // Forced tier 2 compiles eagerly (debug override); otherwise the
        // first translation is always the fast tier-1 path and promotion
        // happens in the background.
        let tier = match self.policy.force {
            Some(JitTier::Optimized) => JitTier::Optimized,
            _ => JitTier::Baseline,
        };
        let t0 = Instant::now();
        let (prog, source) = match self.disk_load(&key, ir_hash, tier) {
            Some(p) => (p, TranslationSource::Disk),
            None => {
                let p = translate_for_key(&key, kernel, simt_cfg, tier)?;
                self.disk_store(&key, ir_hash, tier, &p);
                (p, TranslationSource::Fresh)
            }
        };
        let micros = t0.elapsed().as_secs_f64() * 1e6;

        let mut st = self.state.lock().unwrap();
        if let Some(e) = st.map.get(&key) {
            // Lost the miss race: keep the published program.
            let res = JitResolution {
                prog: e.prog.clone(),
                profile: e.profile.clone(),
                gen: self.generation(),
                tier: e.tier,
                source: e.source,
            };
            st.hits += 1;
            return Ok(res);
        }
        st.push_event(
            self.event_cap,
            JitEvent {
                kernel: key.kernel.clone(),
                kind: key.kind,
                tensix_mode: key.tensix_mode,
                tier,
                micros,
                out_insts: prog.inst_count(),
                source,
            },
        );
        match source {
            TranslationSource::Disk => st.disk_hits += 1,
            _ => match tier {
                JitTier::Baseline => st.tier1_translations += 1,
                JitTier::Optimized => st.tier2_translations += 1,
            },
        }
        let prog = Arc::new(prog);
        let profile = Arc::new(EntryProfile { key: key.clone(), launches: AtomicU64::new(0) });
        let res = JitResolution {
            prog: prog.clone(),
            profile: profile.clone(),
            gen: self.generation(),
            tier,
            source,
        };
        st.map.insert(key, Entry { prog, tier, profile, source });
        Ok(res)
    }

    /// Consult the disk cache for `key` at `tier`; `None` on any miss
    /// (no cache configured, no hash, corrupt/absent entry).
    fn disk_load(
        &self,
        key: &JitKey,
        ir_hash: Option<u128>,
        tier: JitTier,
    ) -> Option<DeviceProgram> {
        let (disk, h) = (self.disk.as_ref()?, ir_hash?);
        disk.load(&CacheKey {
            ir_hash: h,
            kind: key.kind,
            tensix_mode: key.tensix_mode,
            migratable: key.migratable,
            tier,
            kernel: &key.kernel,
        })
    }

    /// Persist a fresh lowering to the disk cache (best-effort, silent).
    fn disk_store(&self, key: &JitKey, ir_hash: Option<u128>, tier: JitTier, prog: &DeviceProgram) {
        if let (Some(disk), Some(h)) = (self.disk.as_ref(), ir_hash) {
            disk.store(
                &CacheKey {
                    ir_hash: h,
                    kind: key.kind,
                    tensix_mode: key.tensix_mode,
                    migratable: key.migratable,
                    tier,
                    kernel: &key.kernel,
                },
                prog,
            );
        }
    }

    /// Count one per-stream memo fast-path hit (launch path, no lock).
    pub fn count_memo_hit(&self) {
        self.memo_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Seed the cache from fat-blob entries for a freshly loaded module
    /// (uid `module_uid`, never seen by any launch yet). Baseline entries
    /// install first so an Optimized payload for the same key wins —
    /// seeded keys start at the top tier with zero translation work, and
    /// the background compiler skips them. Returns how many keys were
    /// seeded. No events, no generation bump: a fresh uid has no memos to
    /// invalidate, and seeding is not a translation.
    pub fn seed_aot(&self, module_uid: u64, entries: Vec<crate::aot::FatEntry>) -> u64 {
        let mut seeded = 0u64;
        let mut st = self.state.lock().unwrap();
        let (base, opt): (Vec<_>, Vec<_>) =
            entries.into_iter().partition(|e| e.tier == JitTier::Baseline);
        for e in base.into_iter().chain(opt) {
            let key = JitKey {
                module: module_uid,
                kernel: e.kernel,
                kind: e.kind,
                tensix_mode: e.tensix_mode,
                migratable: e.migratable,
            };
            match st.map.entry(key.clone()) {
                std::collections::hash_map::Entry::Occupied(mut o) => {
                    // Optimized upgrade over the Baseline seed of the same
                    // key; the profile Arc stays (nothing launched yet).
                    let cur = o.get_mut();
                    if e.tier == JitTier::Optimized && cur.tier == JitTier::Baseline {
                        cur.prog = Arc::new(e.prog);
                        cur.tier = JitTier::Optimized;
                    }
                }
                std::collections::hash_map::Entry::Vacant(v) => {
                    v.insert(Entry {
                        prog: Arc::new(e.prog),
                        tier: e.tier,
                        profile: Arc::new(EntryProfile { key, launches: AtomicU64::new(0) }),
                        source: TranslationSource::Aot,
                    });
                    seeded += 1;
                    st.aot_seeded += 1;
                }
            }
        }
        seeded
    }

    /// Disk-cache counters (`None` when no disk cache is configured).
    pub fn disk_stats(&self) -> Option<CacheStats> {
        self.disk.as_ref().map(|d| d.stats())
    }

    /// The tier currently installed for `key` (`None` when not cached) —
    /// the observability plane attributes memoized launches, whose
    /// resolution bypassed the cache lock, to the right tier with it.
    pub fn entry_tier(&self, key: &JitKey) -> Option<JitTier> {
        self.state.lock().unwrap().map.get(key).map(|e| e.tier)
    }

    /// Count one launch against `profile`; exactly the launch that crosses
    /// the hot threshold queues the key for the background compiler (the
    /// `fetch_add` return value makes the crossing unique even under
    /// concurrent launches from many streams).
    pub fn count_launch(&self, profile: &EntryProfile) {
        let prev = profile.launches.fetch_add(1, Ordering::Relaxed);
        if prev + 1 == self.policy.hot_threshold && self.policy.force.is_none() {
            self.in_flight.fetch_add(1, Ordering::Relaxed);
            let mut q = self.queue.lock().unwrap();
            if q.shutdown {
                drop(q);
                self.in_flight.fetch_sub(1, Ordering::Relaxed);
            } else {
                q.pending.push_back(profile.key.clone());
                self.queue_cond.notify_one();
            }
        }
    }

    /// Block until a hot key is queued (background compile thread); `None`
    /// once [`JitCache::shutdown_compiler`] ran.
    pub fn next_hot(&self) -> Option<JitKey> {
        let mut q = self.queue.lock().unwrap();
        loop {
            if q.shutdown {
                return None;
            }
            if let Some(k) = q.pending.pop_front() {
                return Some(k);
            }
            q = self.queue_cond.wait(q).unwrap();
        }
    }

    /// Wake and terminate the background compiler; queued-but-uncompiled
    /// keys are dropped (context is shutting down).
    pub fn shutdown_compiler(&self) {
        let mut q = self.queue.lock().unwrap();
        q.shutdown = true;
        let dropped = q.pending.len() as u64;
        q.pending.clear();
        self.in_flight.fetch_sub(dropped, Ordering::Relaxed);
        self.queue_cond.notify_all();
    }

    /// Install a finished tier-2 program for `key` and bump the
    /// generation: the swap itself is an `Arc` replacement — in-flight
    /// grids keep the `Arc` they resolved at their launch boundary, the
    /// next launch of the kernel re-resolves (memo generation mismatch)
    /// and picks up tier 2. No launch ever blocks on tier-2 compilation.
    pub fn install_tier2(
        &self,
        key: &JitKey,
        prog: DeviceProgram,
        micros: f64,
        source: TranslationSource,
        ir_hash: Option<u128>,
    ) {
        let out_insts = prog.inst_count();
        if source == TranslationSource::Fresh {
            // Persist the background compile so the next process (or the
            // next context over this cache dir) starts at tier 2.
            self.disk_store(key, ir_hash, JitTier::Optimized, &prog);
        }
        {
            let mut st = self.state.lock().unwrap();
            if let Some(e) = st.map.get_mut(key) {
                e.prog = Arc::new(prog);
                e.tier = JitTier::Optimized;
                e.source = source;
            } else {
                // Module was unloaded while the compile ran; nothing to
                // install (uids are never reused, so this can't alias).
                drop(st);
                self.in_flight.fetch_sub(1, Ordering::Relaxed);
                return;
            }
            match source {
                TranslationSource::Disk => st.disk_hits += 1,
                _ => st.tier2_translations += 1,
            }
            st.promotions += 1;
            st.swaps += 1;
            st.push_event(
                self.event_cap,
                JitEvent {
                    kernel: key.kernel.clone(),
                    kind: key.kind,
                    tensix_mode: key.tensix_mode,
                    tier: JitTier::Optimized,
                    micros,
                    out_insts,
                    source,
                },
            );
        }
        self.in_flight.fetch_sub(1, Ordering::Relaxed);
        self.generation.fetch_add(1, Ordering::Release);
    }

    /// Consult the disk cache for a tier-2 program for `key` (background
    /// compiler fast path: a prior process already paid the optimizing
    /// lowering). `None` = compile fresh.
    pub(crate) fn disk_load_tier2(
        &self,
        key: &JitKey,
        ir_hash: Option<u128>,
    ) -> Option<DeviceProgram> {
        self.disk_load(key, ir_hash, JitTier::Optimized)
    }

    /// The background compiler failed to produce tier-2 code for `key`
    /// (it stays on tier 1 permanently — deterministic, never retried).
    pub fn abandon_promotion(&self, _key: &JitKey) {
        self.in_flight.fetch_sub(1, Ordering::Relaxed);
    }

    /// Drop every cached translation of `module` (called by
    /// `unload_module` so unloading actually releases the translated
    /// programs, not just the IR). The generation is untouched: uids are
    /// never reused, so stale memos miss on the uid compare.
    pub fn evict_module(&self, module: u64) {
        let mut st = self.state.lock().unwrap();
        st.map.retain(|k, _| k.module != module);
    }

    /// Recorded translation events (E4 table data; bounded ring — see
    /// [`JitStats::events_dropped`]).
    pub fn events(&self) -> Vec<JitEvent> {
        self.state.lock().unwrap().events.iter().cloned().collect()
    }

    /// Cache hit count (repeated-launch check, §6.2 "0.11 ms on
    /// subsequent runs (cached)").
    pub fn hit_count(&self) -> u64 {
        self.state.lock().unwrap().hits
    }

    /// Aggregate tiering/translation counters.
    pub fn stats(&self) -> JitStats {
        let st = self.state.lock().unwrap();
        JitStats {
            hits: st.hits,
            memo_hits: self.memo_hits.load(Ordering::Relaxed),
            disk_hits: st.disk_hits,
            aot_seeded: st.aot_seeded,
            tier1_translations: st.tier1_translations,
            tier2_translations: st.tier2_translations,
            promotions: st.promotions,
            in_flight_compiles: self.in_flight.load(Ordering::Relaxed),
            swaps: st.swaps,
            generation: self.generation(),
            events_dropped: st.events_dropped,
        }
    }

    #[cfg(test)]
    fn with_event_cap(policy: TierPolicy, cap: usize) -> JitCache {
        let mut c = JitCache::with_policy(policy);
        c.event_cap = cap;
        c
    }
}

/// Lower `kernel` for the target identified by `key` at the given tier.
/// Shared by the launch path and the background compiler.
pub(crate) fn translate_for_key(
    key: &JitKey,
    kernel: &Kernel,
    simt_cfg: Option<&SimtConfig>,
    tier: JitTier,
) -> Result<DeviceProgram> {
    let opts = TranslateOpts { migratable: key.migratable, tier };
    Ok(match key.kind {
        DeviceKind::TenstorrentSim => {
            let mode = key.tensix_mode.expect("tensix mode required");
            DeviceProgram::Tensix(backends::translate_tensix(kernel, mode, opts)?)
        }
        _ => {
            let cfg = simt_cfg.expect("simt config required");
            DeviceProgram::Simt(backends::translate_simt(kernel, cfg, opts)?)
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hetir::builder::KernelBuilder;
    use crate::hetir::types::Type;

    fn tiny_kernel() -> Kernel {
        let mut b = KernelBuilder::new("k");
        let _p = b.param("p", Type::PTR_GLOBAL);
        b.finish()
    }

    fn nv_key(module: u64) -> JitKey {
        JitKey {
            module,
            kernel: "k".into(),
            kind: DeviceKind::NvidiaSim,
            tensix_mode: None,
            migratable: true,
        }
    }

    #[test]
    fn caches_by_key() {
        let cache = JitCache::new();
        let k = tiny_kernel();
        let cfg = SimtConfig::nvidia();
        let a = cache.get_or_translate(nv_key(0), &k, Some(&cfg), None).unwrap();
        let b = cache.get_or_translate(nv_key(0), &k, Some(&cfg), None).unwrap();
        assert!(Arc::ptr_eq(&a.prog, &b.prog));
        assert!(Arc::ptr_eq(&a.profile, &b.profile));
        assert_eq!(cache.hit_count(), 1);
        assert_eq!(cache.events().len(), 1);
        assert_eq!(cache.events()[0].tier, JitTier::Baseline);
        assert_eq!(cache.stats().tier1_translations, 1);
    }

    #[test]
    fn concurrent_misses_record_one_event_and_share_one_program() {
        let cache = JitCache::new();
        let k = tiny_kernel();
        let cfg = SimtConfig::nvidia();
        let progs: Vec<Arc<DeviceProgram>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    s.spawn(|| {
                        cache.get_or_translate(nv_key(0), &k, Some(&cfg), None).unwrap().prog
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(cache.events().len(), 1, "duplicate JitEvents recorded");
        for p in &progs[1..] {
            assert!(Arc::ptr_eq(&progs[0], p), "threads saw different programs");
        }
        // Exactly one miss translated-and-published; the other 7 hit
        // (either before translating or when they lost the publish race).
        assert_eq!(cache.hit_count(), 7);
    }

    #[test]
    fn different_targets_translate_separately() {
        let cache = JitCache::new();
        let k = tiny_kernel();
        let cfg = SimtConfig::nvidia();
        let mk = |kind, mode| JitKey {
            module: 0,
            kernel: "k".into(),
            kind,
            tensix_mode: mode,
            migratable: true,
        };
        cache.get_or_translate(mk(DeviceKind::NvidiaSim, None), &k, Some(&cfg), None).unwrap();
        cache
            .get_or_translate(
                mk(DeviceKind::TenstorrentSim, Some(TensixMode::VectorSingleCore)),
                &k,
                None,
                None,
            )
            .unwrap();
        assert_eq!(cache.events().len(), 2);
        assert_eq!(cache.hit_count(), 0);
    }

    #[test]
    fn event_ring_is_bounded_but_counters_stay_exact() {
        let cache = JitCache::with_event_cap(TierPolicy::default(), 2);
        let k = tiny_kernel();
        let cfg = SimtConfig::nvidia();
        for m in 0..3 {
            cache.get_or_translate(nv_key(m), &k, Some(&cfg), None).unwrap();
        }
        assert_eq!(cache.events().len(), 2, "ring capped");
        let st = cache.stats();
        assert_eq!(st.events_dropped, 1);
        assert_eq!(st.tier1_translations, 3, "aggregate counter exact");
    }

    #[test]
    fn threshold_crossing_promotes_and_swaps_at_generation_bump() {
        let cache = JitCache::with_policy(TierPolicy { hot_threshold: 2, force: None });
        let k = tiny_kernel();
        let cfg = SimtConfig::nvidia();
        let res = cache.get_or_translate(nv_key(0), &k, Some(&cfg), None).unwrap();
        let g0 = cache.generation();
        assert_eq!(res.gen, g0);

        // Launch 1: below threshold — nothing queued, nothing in flight.
        cache.count_launch(&res.profile);
        assert_eq!(cache.stats().in_flight_compiles, 0);
        // Launch 2: crosses the threshold exactly once.
        cache.count_launch(&res.profile);
        assert_eq!(cache.stats().in_flight_compiles, 1);
        // Launch 3: already crossed — must not re-queue.
        cache.count_launch(&res.profile);
        assert_eq!(cache.stats().in_flight_compiles, 1);

        let hot = cache.next_hot().expect("hot key queued");
        assert_eq!(hot, nv_key(0));
        let prog = translate_for_key(&hot, &k, Some(&cfg), JitTier::Optimized).unwrap();
        cache.install_tier2(&hot, prog, 1.0, TranslationSource::Fresh, None);

        assert_eq!(cache.generation(), g0 + 1, "swap bumps the generation");
        let st = cache.stats();
        assert_eq!(
            (st.promotions, st.swaps, st.tier2_translations, st.in_flight_compiles),
            (1, 1, 1, 0)
        );

        // The stream memo taken at g0 must refuse its stale program now.
        let memo = JitMemo::new(0, "k".into(), DeviceKind::NvidiaSim, None, &res);
        assert!(memo.lookup(0, "k", DeviceKind::NvidiaSim, None, g0).is_some());
        assert!(
            memo.lookup(0, "k", DeviceKind::NvidiaSim, None, cache.generation()).is_none(),
            "memo must revalidate on generation mismatch"
        );

        // Re-resolution at the launch boundary returns the tier-2 program.
        let res2 = cache.get_or_translate(nv_key(0), &k, Some(&cfg), None).unwrap();
        assert!(!Arc::ptr_eq(&res.prog, &res2.prog), "swap visible to next launch");
        assert!(Arc::ptr_eq(&res.profile, &res2.profile), "profile survives the swap");
    }

    #[test]
    fn forced_tiers_disable_the_background_path() {
        // Forced baseline: threshold crossings never queue.
        let cache =
            JitCache::with_policy(TierPolicy { hot_threshold: 1, force: Some(JitTier::Baseline) });
        let k = tiny_kernel();
        let cfg = SimtConfig::nvidia();
        let res = cache.get_or_translate(nv_key(0), &k, Some(&cfg), None).unwrap();
        cache.count_launch(&res.profile);
        cache.count_launch(&res.profile);
        assert_eq!(cache.stats().in_flight_compiles, 0);
        assert_eq!(cache.stats().tier2_translations, 0);
        cache.shutdown_compiler();
        assert!(cache.next_hot().is_none());

        // Forced optimized: tier 2 eagerly, still no background traffic.
        let cache =
            JitCache::with_policy(TierPolicy { hot_threshold: 1, force: Some(JitTier::Optimized) });
        let res = cache.get_or_translate(nv_key(0), &k, Some(&cfg), None).unwrap();
        cache.count_launch(&res.profile);
        let st = cache.stats();
        assert_eq!(st.tier2_translations, 1);
        assert_eq!(st.tier1_translations, 0);
        assert_eq!(st.in_flight_compiles, 0);
        assert_eq!(st.promotions, 0, "eager tier 2 is not a promotion");
        assert_eq!(cache.events()[0].tier, JitTier::Optimized);
        let _ = res;
    }

    #[test]
    fn shutdown_drains_pending_queue() {
        let cache = JitCache::with_policy(TierPolicy { hot_threshold: 1, force: None });
        let k = tiny_kernel();
        let cfg = SimtConfig::nvidia();
        let res = cache.get_or_translate(nv_key(0), &k, Some(&cfg), None).unwrap();
        cache.count_launch(&res.profile);
        assert_eq!(cache.stats().in_flight_compiles, 1);
        cache.shutdown_compiler();
        assert!(cache.next_hot().is_none(), "shutdown wins over pending work");
        assert_eq!(cache.stats().in_flight_compiles, 0);
        // Crossings after shutdown are dropped cleanly too.
        let res2 = cache.get_or_translate(nv_key(1), &k, Some(&cfg), None).unwrap();
        cache.count_launch(&res2.profile);
        assert_eq!(cache.stats().in_flight_compiles, 0);
    }

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("hetgpu-jit-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn disk_cache_serves_a_second_cache_without_lowering() {
        use crate::aot::{DiskCache, DiskCacheConfig};
        let dir = tmpdir("share");
        let mkdisk =
            || DiskCache::new(DiskCacheConfig { dir: dir.clone(), max_mb: 64 }).unwrap();
        let k = tiny_kernel();
        let cfg = SimtConfig::nvidia();
        let h = Some(42u128);

        let a = JitCache::with_policy_and_disk(TierPolicy::default(), Some(mkdisk()));
        let ra = a.get_or_translate(nv_key(0), &k, Some(&cfg), h).unwrap();
        assert_eq!(ra.source, TranslationSource::Fresh);
        assert_eq!((a.stats().tier1_translations, a.stats().disk_hits), (1, 0));

        // A second cache over the same dir (a "second process"): the miss
        // is satisfied from disk, zero lowering, same program bits.
        let b = JitCache::with_policy_and_disk(TierPolicy::default(), Some(mkdisk()));
        let rb = b.get_or_translate(nv_key(7), &k, Some(&cfg), h).unwrap();
        assert_eq!(rb.source, TranslationSource::Disk);
        assert_eq!((b.stats().tier1_translations, b.stats().disk_hits), (0, 1));
        assert_eq!(*ra.prog, *rb.prog, "disk round-trip must be bit-identical");
        assert_eq!(b.events().len(), 1);
        assert_eq!(b.events()[0].source, TranslationSource::Disk);

        // A different IR hash misses (content addressing, not key reuse).
        let rc = b.get_or_translate(nv_key(8), &k, Some(&cfg), Some(43)).unwrap();
        assert_eq!(rc.source, TranslationSource::Fresh);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn seed_aot_installs_top_tier_with_zero_translations() {
        let cache = JitCache::new();
        let k = tiny_kernel();
        let cfg = SimtConfig::nvidia();
        let key = nv_key(3);
        let t1 = translate_for_key(&key, &k, Some(&cfg), JitTier::Baseline).unwrap();
        let t2 = translate_for_key(&key, &k, Some(&cfg), JitTier::Optimized).unwrap();
        let mk = |tier, prog| crate::aot::FatEntry {
            kernel: "k".into(),
            kind: DeviceKind::NvidiaSim,
            tensix_mode: None,
            migratable: true,
            tier,
            prog,
        };
        // Optimized listed first: seeding must still end Optimized (the
        // Baseline→Optimized ordering is internal, not caller-supplied).
        let seeded = cache.seed_aot(3, vec![mk(JitTier::Optimized, t2), mk(JitTier::Baseline, t1)]);
        assert_eq!(seeded, 1, "two tiers of one key seed one entry");
        assert_eq!(cache.entry_tier(&key), Some(JitTier::Optimized));

        let res = cache.get_or_translate(key, &k, Some(&cfg), None).unwrap();
        assert_eq!(res.source, TranslationSource::Aot);
        assert_eq!(res.tier, JitTier::Optimized);
        let st = cache.stats();
        assert_eq!(st.aot_seeded, 1);
        assert_eq!(st.hits, 1, "seeded entry resolves as a cache hit");
        assert_eq!((st.tier1_translations, st.tier2_translations), (0, 0));
        assert!(cache.events().is_empty(), "seeding is not a translation event");
    }

    #[test]
    fn memo_hits_are_counted_apart_from_cache_hits() {
        let cache = JitCache::new();
        cache.count_memo_hit();
        cache.count_memo_hit();
        let st = cache.stats();
        assert_eq!(st.memo_hits, 2);
        assert_eq!(st.hits, 0);
    }

    #[test]
    fn env_parsers_follow_the_sim_threads_contract() {
        assert_eq!(parse_hot_threshold("64"), (64, None));
        assert_eq!(parse_hot_threshold(" 8 "), (8, None));
        assert_eq!(parse_hot_threshold("0"), (1, None), "0 clamps to promote-on-first");
        let (v, warn) = parse_hot_threshold("banana");
        assert_eq!(v, DEFAULT_HOT_THRESHOLD);
        let warn = warn.expect("malformed threshold must warn");
        assert!(warn.contains("banana") && warn.contains("32"), "{warn}");

        assert_eq!(parse_forced_tier("1"), (Some(JitTier::Baseline), None));
        assert_eq!(parse_forced_tier("2"), (Some(JitTier::Optimized), None));
        let (f, warn) = parse_forced_tier("coffee");
        assert_eq!(f, None);
        let warn = warn.expect("malformed tier must warn");
        assert!(warn.contains("coffee"), "{warn}");
    }
}
