//! Deterministic fault-injection plane.
//!
//! Real GPUs fail mid-kernel: an Xid on one board, a flaky PCIe link
//! corrupting a DMA, a migration blob truncated on the wire. The paper's
//! state capture/reload machinery exists to survive exactly this, so the
//! runtime needs a way to *cause* those failures on demand — seeded and
//! programmable, so every failure mode is bit-reproducible in tests and
//! benches. A [`FaultPlan`] describes which operations fail ("device 1's
//! first launch node, at block offset 3"; "the next D2H on device 0";
//! "the next migration blob"); the [`FaultInjector`] installed on the
//! runtime arms it and fires each spec deterministically by per-device
//! operation count, never by wall clock or thread timing.
//!
//! Plans install through [`crate::runtime::api::HetGpu::install_fault_plan`]
//! or the `HETGPU_FAULT_PLAN` environment variable (see [`FaultPlan::parse`]
//! for the grammar). With no plan installed the plane costs one relaxed
//! atomic load per operation — the fault-free path pays nothing measurable.

use crate::error::{HetError, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

/// The operation classes a fault spec can target.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultKind {
    /// Fail a kernel launch node mid-grid (at a block offset).
    Launch,
    /// Fail a peer/broadcast copy (coordinator working-set distribution).
    Broadcast,
    /// Fail a device-to-host copy (sync or async D2H nodes).
    D2h,
    /// Corrupt the next serialized migration/rebalance blob.
    Blob,
}

impl FaultKind {
    fn parse(s: &str) -> Option<FaultKind> {
        Some(match s {
            "launch" => FaultKind::Launch,
            "broadcast" => FaultKind::Broadcast,
            "d2h" => FaultKind::D2h,
            "blob" => FaultKind::Blob,
            _ => return None,
        })
    }

    fn name(self) -> &'static str {
        match self {
            FaultKind::Launch => "launch",
            FaultKind::Broadcast => "broadcast",
            FaultKind::D2h => "d2h",
            FaultKind::Blob => "blob",
        }
    }
}

/// One programmed fault: fire on the `nth` matching operation (counted
/// per device from plan installation), `times` consecutive times.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultSpec {
    pub kind: FaultKind,
    /// Restrict to one device id; `None` matches any device (`Blob`
    /// specs ignore the device entirely — blobs are host-side).
    pub device: Option<usize>,
    /// Zero-based index of the first matching operation that fails.
    pub nth: u64,
    /// For `Launch`: block offset *relative to the executed range* at
    /// which the grid faults (the injector cannot know shard ranges; the
    /// executor resolves the absolute block id).
    pub block: u32,
    /// How many consecutive matching operations fail; `0` means every
    /// one from `nth` on (a permanently dead device/link).
    pub times: u32,
}

/// A parsed, installable set of fault specs plus the seed that makes
/// value-level corruption (blob byte flips) reproducible.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct FaultPlan {
    pub specs: Vec<FaultSpec>,
    pub seed: u64,
}

impl FaultPlan {
    /// Parse the `HETGPU_FAULT_PLAN` grammar: semicolon-separated specs
    /// of the form `kind:key=val,...` plus an optional `seed=N` item.
    ///
    /// Kinds: `launch`, `broadcast`, `d2h`, `blob`. Keys: `dev` (device
    /// id; omitted = any), `nth` (default 0), `block` (launch only,
    /// default 0), `times` (default 1; 0 = always). Examples:
    ///
    /// ```text
    /// launch:dev=1,nth=0,block=3
    /// d2h:dev=0,times=2;blob:nth=0;seed=42
    /// ```
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for item in s.split(';').map(str::trim).filter(|i| !i.is_empty()) {
            if let Some(seed) = item.strip_prefix("seed=") {
                plan.seed = seed
                    .parse()
                    .map_err(|_| HetError::runtime(format!("fault plan: bad seed {seed:?}")))?;
                continue;
            }
            let (kind, rest) = match item.split_once(':') {
                Some((k, r)) => (k, r),
                None => (item, ""),
            };
            let kind = FaultKind::parse(kind).ok_or_else(|| {
                HetError::runtime(format!(
                    "fault plan: unknown fault kind {kind:?} (want launch|broadcast|d2h|blob)"
                ))
            })?;
            let mut spec = FaultSpec { kind, device: None, nth: 0, block: 0, times: 1 };
            for kv in rest.split(',').map(str::trim).filter(|kv| !kv.is_empty()) {
                let (key, val) = kv.split_once('=').ok_or_else(|| {
                    HetError::runtime(format!("fault plan: expected key=value, got {kv:?}"))
                })?;
                let num: u64 = val.parse().map_err(|_| {
                    HetError::runtime(format!("fault plan: {key}={val:?} is not a number"))
                })?;
                match key {
                    "dev" => spec.device = Some(num as usize),
                    "nth" => spec.nth = num,
                    "block" => spec.block = num as u32,
                    "times" => spec.times = num as u32,
                    _ => {
                        return Err(HetError::runtime(format!(
                            "fault plan: unknown key {key:?} (want dev|nth|block|times)"
                        )))
                    }
                }
            }
            plan.specs.push(spec);
        }
        Ok(plan)
    }

    /// Read `HETGPU_FAULT_PLAN`. Unset means no plan; a malformed value
    /// warns loudly **once** (naming the bad value and the no-faults
    /// fallback — the same contract `HETGPU_SIM_THREADS` has) and is
    /// treated as absent.
    pub fn from_env() -> Option<FaultPlan> {
        let raw = std::env::var("HETGPU_FAULT_PLAN").ok()?;
        match FaultPlan::parse(&raw) {
            Ok(plan) if plan.specs.is_empty() => None,
            Ok(plan) => Some(plan),
            Err(e) => {
                static WARNED: std::sync::Once = std::sync::Once::new();
                WARNED.call_once(|| {
                    eprintln!(
                        "hetgpu: HETGPU_FAULT_PLAN={raw:?} is invalid ({e}); \
                         falling back to no injected faults"
                    );
                });
                None
            }
        }
    }
}

/// How a sharded launch responds to a shard fault. Set per launch via
/// `LaunchBuilder::fault_policy`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FaultPolicy {
    /// Surface a typed [`HetError::DeviceLost`] immediately; the faulted
    /// device is quarantined, survivors' work is discarded.
    #[default]
    FailFast,
    /// Re-execute the failed shard on the *same* device up to `max`
    /// times with capped exponential backoff; quarantine + `DeviceLost`
    /// when exhausted.
    Retry { max: u32 },
    /// Quarantine the faulted device and re-execute its block range on
    /// the surviving shards' devices, from the launch baseline. The join
    /// is bit-identical to the fault-free run.
    Redistribute,
}

/// Cumulative fault-plane counters (per context, monotonic).
#[derive(Default)]
pub struct FaultCounters {
    /// Faults the injector fired.
    pub injected: AtomicU64,
    /// Device faults the event-graph executor observed (injected or
    /// organic).
    pub observed: AtomicU64,
    /// Retry attempts (copy-node retries + same-device shard retries).
    pub retries: AtomicU64,
    /// Shards whose work was recovered (same-device retry success or
    /// redistribute to survivors).
    pub recoveries: AtomicU64,
    /// Devices moved to `Quarantined`.
    pub quarantines: AtomicU64,
}

/// Snapshot of [`FaultCounters`], returned by `HetGpu::fault_stats`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FaultStats {
    pub injected: u64,
    pub observed: u64,
    pub retries: u64,
    pub recoveries: u64,
    pub quarantines: u64,
}

/// Armed spec plus how many times it has fired.
struct Armed {
    spec: FaultSpec,
    fired: u32,
}

impl Armed {
    /// Whether operation number `n` (per-device, per-kind) fires this
    /// spec; advances the fired count when it does.
    fn fires(&mut self, kind: FaultKind, device: Option<usize>, n: u64) -> bool {
        if self.spec.kind != kind {
            return false;
        }
        if let (Some(want), Some(have)) = (self.spec.device, device) {
            if want != have {
                return false;
            }
        }
        if n < self.spec.nth {
            return false;
        }
        if self.spec.times != 0 && self.fired >= self.spec.times {
            return false;
        }
        self.fired += 1;
        true
    }
}

#[derive(Default)]
struct InjectState {
    specs: Vec<Armed>,
    seed: u64,
    /// Per-device launch-node counters (operation ordinals are counted
    /// from plan installation, per device — deterministic regardless of
    /// executor interleaving because each stream's nodes run FIFO).
    launch_seq: HashMap<usize, u64>,
    /// Per-(device, kind) copy-node counters.
    copy_seq: HashMap<(usize, FaultKind), u64>,
    /// Host-side blob serialization counter.
    blob_seq: u64,
}

/// The per-context injector: holds the armed plan and the observability
/// counters. Lives on `RuntimeInner`; all hooks are `&self`.
#[derive(Default)]
pub struct FaultInjector {
    /// Fast-path gate: false whenever no plan is installed, so the
    /// disabled plane costs one relaxed load per hooked operation.
    armed: AtomicBool,
    state: Mutex<InjectState>,
    pub(crate) counters: FaultCounters,
}

impl FaultInjector {
    /// Install (or replace) the active plan; operation counters restart
    /// from zero so `nth` is relative to installation.
    pub fn install(&self, plan: FaultPlan) {
        let mut st = self.state.lock().unwrap();
        let any = !plan.specs.is_empty();
        *st = InjectState {
            specs: plan.specs.into_iter().map(|spec| Armed { spec, fired: 0 }).collect(),
            seed: plan.seed,
            ..InjectState::default()
        };
        self.armed.store(any, Ordering::Release);
    }

    /// Hook for launch nodes: returns the block offset (relative to the
    /// executed range) at which this launch must fault, if any.
    pub fn launch_fault(&self, device: usize) -> Option<u32> {
        if !self.armed.load(Ordering::Acquire) {
            return None;
        }
        let mut st = self.state.lock().unwrap();
        let seq = st.launch_seq.entry(device).or_insert(0);
        let n = *seq;
        *seq += 1;
        let block = st
            .specs
            .iter_mut()
            .find_map(|a| a.fires(FaultKind::Launch, Some(device), n).then_some(a.spec.block));
        if block.is_some() {
            self.counters.injected.fetch_add(1, Ordering::Relaxed);
        }
        block
    }

    /// Hook for copy nodes (`Broadcast` for peer copies, `D2h` for
    /// device-to-host): returns the fault message when the copy must
    /// fail.
    pub fn copy_fault(&self, device: usize, kind: FaultKind) -> Option<String> {
        if !self.armed.load(Ordering::Acquire) {
            return None;
        }
        let mut st = self.state.lock().unwrap();
        let seq = st.copy_seq.entry((device, kind)).or_insert(0);
        let n = *seq;
        *seq += 1;
        let fires = st.specs.iter_mut().any(|a| a.fires(kind, Some(device), n));
        if fires {
            self.counters.injected.fetch_add(1, Ordering::Relaxed);
            Some(format!("injected {} fault (op {n} on device {device})", kind.name()))
        } else {
            None
        }
    }

    /// Hook for blob serialization: deterministically flips one header
    /// byte (seeded offset within the first 16 bytes, where the magic /
    /// version / src-device / stream fields live, so deserialization or
    /// the epoch check reliably fails). Returns whether it fired.
    pub fn corrupt_blob(&self, bytes: &mut [u8]) -> bool {
        if !self.armed.load(Ordering::Acquire) || bytes.is_empty() {
            return false;
        }
        let mut st = self.state.lock().unwrap();
        let n = st.blob_seq;
        st.blob_seq += 1;
        let fires = st.specs.iter_mut().any(|a| a.fires(FaultKind::Blob, None, n));
        if !fires {
            return false;
        }
        // xorshift64 over seed + ordinal: reproducible, never zero-state.
        let mut x = st.seed.wrapping_add(n).wrapping_mul(0x9E3779B97F4A7C15) | 1;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        let off = (x as usize) % bytes.len().min(16);
        bytes[off] ^= 0x5A;
        self.counters.injected.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Snapshot the counters.
    pub fn stats(&self) -> FaultStats {
        FaultStats {
            injected: self.counters.injected.load(Ordering::Relaxed),
            observed: self.counters.observed.load(Ordering::Relaxed),
            retries: self.counters.retries.load(Ordering::Relaxed),
            recoveries: self.counters.recoveries.load(Ordering::Relaxed),
            quarantines: self.counters.quarantines.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_grammar() {
        let plan = FaultPlan::parse("launch:dev=1,nth=2,block=3;d2h:times=0;blob:nth=1;seed=42")
            .unwrap();
        assert_eq!(plan.seed, 42);
        assert_eq!(plan.specs.len(), 3);
        assert_eq!(
            plan.specs[0],
            FaultSpec { kind: FaultKind::Launch, device: Some(1), nth: 2, block: 3, times: 1 }
        );
        assert_eq!(
            plan.specs[1],
            FaultSpec { kind: FaultKind::D2h, device: None, nth: 0, block: 0, times: 0 }
        );
        assert_eq!(plan.specs[2].kind, FaultKind::Blob);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("explode:dev=0").is_err());
        assert!(FaultPlan::parse("launch:dev=abc").is_err());
        assert!(FaultPlan::parse("launch:color=red").is_err());
        assert!(FaultPlan::parse("seed=many").is_err());
        assert!(FaultPlan::parse("launch dev 0").is_err());
    }

    #[test]
    fn launch_fault_fires_on_nth_per_device() {
        let inj = FaultInjector::default();
        inj.install(FaultPlan::parse("launch:dev=1,nth=1,block=7").unwrap());
        // Device 0 never matches; device 1 fires on its *second* launch.
        assert_eq!(inj.launch_fault(0), None);
        assert_eq!(inj.launch_fault(1), None);
        assert_eq!(inj.launch_fault(1), Some(7));
        assert_eq!(inj.launch_fault(1), None); // times=1: armed once
        assert_eq!(inj.stats().injected, 1);
    }

    #[test]
    fn times_zero_fires_forever() {
        let inj = FaultInjector::default();
        inj.install(FaultPlan::parse("d2h:dev=0,times=0").unwrap());
        for _ in 0..4 {
            assert!(inj.copy_fault(0, FaultKind::D2h).is_some());
        }
        assert!(inj.copy_fault(1, FaultKind::D2h).is_none());
        assert!(inj.copy_fault(0, FaultKind::Broadcast).is_none());
    }

    #[test]
    fn blob_corruption_is_deterministic() {
        let reference = {
            let inj = FaultInjector::default();
            inj.install(FaultPlan::parse("blob;seed=9").unwrap());
            let mut b = vec![0u8; 64];
            assert!(inj.corrupt_blob(&mut b));
            b
        };
        let inj = FaultInjector::default();
        inj.install(FaultPlan::parse("blob;seed=9").unwrap());
        let mut b = vec![0u8; 64];
        assert!(inj.corrupt_blob(&mut b));
        assert_eq!(b, reference);
        assert_ne!(b, vec![0u8; 64]);
        assert!(b[..16].iter().any(|&x| x != 0), "corruption must land in the header");
        // Second blob: spec exhausted (times=1) — untouched.
        let mut c = vec![0u8; 64];
        assert!(!inj.corrupt_blob(&mut c));
        assert_eq!(c, vec![0u8; 64]);
    }

    #[test]
    fn uninstalled_plane_is_inert() {
        let inj = FaultInjector::default();
        assert_eq!(inj.launch_fault(0), None);
        assert!(inj.copy_fault(0, FaultKind::D2h).is_none());
        let mut b = vec![1u8; 8];
        assert!(!inj.corrupt_blob(&mut b));
        assert_eq!(inj.stats(), FaultStats::default());
    }
}
