//! The public hetGPU API v2 — the CUDA-driver-style abstraction layer of
//! paper §4.3, rebuilt around **generational typed handles with full
//! lifecycles**.
//!
//! `HetGpu` is the context a program links against (`libhetgpu.so` in the
//! paper). Every resource it hands out is a `{slot, generation}` handle
//! backed by a slot-reuse table, with a matching destroy path:
//!
//! | resource | create                         | destroy                  |
//! |----------|--------------------------------|--------------------------|
//! | module   | [`HetGpu::load_module`]        | [`HetGpu::unload_module`]|
//! | buffer   | [`HetGpu::alloc_buffer`]       | [`HetGpu::free_buffer`]  |
//! | stream   | [`HetGpu::create_stream`]      | [`HetGpu::destroy_stream`]|
//! | event    | recorded by launches/copies    | [`HetGpu::retire_event`] |
//!
//! Stale handles of every type — destroyed, double-destroyed, or minted
//! before the slot was reused — fail with
//! [`HetError::InvalidHandle`](crate::error::HetError::InvalidHandle)
//! instead of silently indexing a table. Terminal event statuses are
//! garbage-collected once **unreferenced**: an event stays queryable (=
//! referenced) while its creator holds it, until [`HetGpu::retire_event`]
//! or its stream's destruction. Internal events (coordinator shards,
//! migration resumes) release themselves, so `launch_sharded` loops and
//! migration loops hold the graph at constant size; a service recording
//! forever on one *long-lived* stream should retire the `EventId`s it
//! does not intend to query again (or periodically destroy/recreate the
//! stream) — see [`HetGpu::graph_stats`] for the observability hook.
//!
//! Kernel launches go through the [`LaunchBuilder`] (dims, typed args,
//! Tensix mode hint, coordinator working-set hint), and copies through a
//! unified surface: generic typed [`HetGpu::upload`]/[`HetGpu::download`]
//! over [`Buffer`], raw synchronous [`HetGpu::memcpy_h2d`]/
//! [`HetGpu::memcpy_d2h`], and stream-ordered asynchronous
//! [`HetGpu::memcpy_h2d_async`], [`HetGpu::memcpy_d2h_async`] (into
//! pinned host buffers) and [`HetGpu::memcpy_peer_async`] (between device
//! arenas).

pub use crate::aot::{CacheStats, DiskCacheConfig};
use crate::aot::{self, DiskCache};
use crate::coordinator::shard::ShardRange;
use crate::coordinator::{CoordCache, Coordinator, ShardedLaunch};
use crate::delta::capture::capture_spans;
use crate::delta::journal::AtomicJournal;
use crate::delta::tracker::DirtyStats;
use crate::error::{HetError, Result};
use crate::frontend;
use crate::hetir::analyze::{self, Severity};
pub use crate::hetir::analyze::{AnalysisLevel, AnalysisReport};
use crate::hetir::{self, module::Module};
use crate::isa::tensix_isa::TensixMode;
use crate::migrate::state::{MigrationReport, Snapshot};
use crate::obs::{KernelProfile, Phase, PhaseStats, ProfileKey, SpanEvent};
use crate::runtime::device::{Device, DeviceKind};
use crate::runtime::events::{copy_end, EventGraph, EventId, EventStatus, GraphStats, NodeKind};
use crate::runtime::faultinject::FaultInjector;
use crate::runtime::jit::JitCache;
pub use crate::backends::JitTier;
pub use crate::runtime::jit::{JitStats, TierPolicy};
use crate::runtime::launch::{Arg, LaunchSpec};
use crate::runtime::memory::{
    pod_from_bytes, pod_to_bytes, Buffer, GpuPtr, MemoryManager, PinnedBuffer, Pod,
};
use crate::runtime::stream::StreamStats;
use crate::runtime::{ModuleTable, RuntimeInner};
use crate::sim::simt::LaunchDims;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

// Handle types live next to their backing tables; re-exported here so the
// public API surface reads from one place (`api::{HetGpu, ModuleHandle,
// StreamHandle, ...}`).
pub use crate::runtime::device::HealthState;
pub use crate::runtime::faultinject::{FaultPlan, FaultPolicy, FaultStats};
pub use crate::runtime::launch::AtomicsMode;
pub use crate::runtime::stream::StreamHandle;
pub use crate::runtime::ModuleHandle;
use std::thread::JoinHandle;
use std::time::Instant;

/// The hetGPU context.
pub struct HetGpu {
    inner: Arc<RuntimeInner>,
    /// The command DAG every stream records into — the single source of
    /// stream identity (there is no second host-side registry to skew
    /// against it).
    graph: Arc<EventGraph>,
    /// Executor pool draining the graph (joined on drop).
    executors: Vec<JoinHandle<()>>,
    /// Background tier-2 JIT compiler (None when a forced tier disables
    /// adaptive promotion); shut down and joined on drop.
    jit_compiler: Option<JoinHandle<()>>,
    /// The coordinator's persistent delta-sync state: host baseline
    /// mirror + per-device sync watermarks (see `coordinator::CoordCache`),
    /// so repeated `launch_sharded` calls baseline/broadcast/merge
    /// O(dirty pages) instead of O(total memory).
    pub(crate) coord: Mutex<CoordCache>,
    /// Cross-shard atomics-journal counters ([`HetGpu::journal_stats`]).
    pub(crate) journal_counters: JournalCounters,
    /// Context-default analysis gating level, resolved from
    /// `HETGPU_ANALYZE` at creation; `LaunchBuilder::analysis` overrides
    /// it per launch.
    pub(crate) analysis_default: AnalysisLevel,
    /// Static-analyzer counters ([`HetGpu::analysis_stats`]).
    pub(crate) analysis_counters: AnalysisCounters,
}

/// Context-lifetime counters of the cross-shard atomics protocol,
/// maintained by the coordinator (creation at `launch_sharded`, replay at
/// join, shipping at rebalance).
#[derive(Default)]
pub(crate) struct JournalCounters {
    pub(crate) journaled_launches: AtomicU64,
    pub(crate) ops_replayed: AtomicU64,
    pub(crate) entries_shipped: AtomicU64,
}

/// Context-lifetime counters of the static analyzer (DESIGN.md §12):
/// analysis work at module load, launch pre-flights, and static launch
/// rejections.
#[derive(Default)]
pub(crate) struct AnalysisCounters {
    pub(crate) kernels_analyzed: AtomicU64,
    pub(crate) diags_info: AtomicU64,
    pub(crate) diags_warning: AtomicU64,
    pub(crate) diags_error: AtomicU64,
    pub(crate) preflight_checks: AtomicU64,
    pub(crate) preflight_rejections: AtomicU64,
    pub(crate) analysis_nanos: AtomicU64,
}

/// Snapshot of the context's static-analyzer counters
/// ([`HetGpu::analysis_stats`]) — the `graph_stats`-style observability
/// hook of the analysis plane.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct AnalysisStats {
    /// Kernels the analyzer has processed. Analysis runs once per
    /// `(module, kernel)` — cached reports do not recount, so this stays
    /// flat across repeat launches.
    pub kernels_analyzed: u64,
    /// Diagnostics produced, by severity.
    pub diags_info: u64,
    pub diags_warning: u64,
    pub diags_error: u64,
    /// Launch pre-flights performed (launches at `Strict` or `Warn`).
    pub preflight_checks: u64,
    /// Launches rejected statically (`HetError::StaticFault`) before any
    /// block executed.
    pub preflight_rejections: u64,
    /// Total wall time spent inside the analyzer, in nanoseconds.
    pub analysis_nanos: u64,
}

/// Snapshot of the context's cross-shard atomics-journal counters — the
/// `graph_stats`-style observability hook of the atomics protocol
/// ([`HetGpu::journal_stats`]). Per-launch byte/op accounting lives in
/// `ShardReport::io` (`journal_ops` / `journal_bytes`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct JournalStats {
    /// Sharded launches that ran under the journal protocol.
    pub journaled_launches: u64,
    /// Journal entries replayed against peer images at joins.
    pub ops_replayed: u64,
    /// Journal entries shipped through rebalance delta blobs.
    pub entries_shipped: u64,
}

/// One unified snapshot of every counter plane in the context, returned
/// by [`HetGpu::metrics`] (DESIGN.md §13): the six legacy `*_stats()`
/// structs folded side by side, the per-phase launch-lifecycle latency
/// histograms of the observability plane, the per-kernel execution
/// profiles harvested from the simulators while tracing is armed, and the
/// flight recorder's drop counter.
#[derive(Debug, Clone)]
pub struct Metrics {
    /// Tiered-JIT counters ([`HetGpu::jit_stats`]).
    pub jit: JitStats,
    /// On-disk translation-cache counters ([`HetGpu::cache_stats`]).
    pub cache: CacheStats,
    /// Fault-plane counters ([`HetGpu::fault_stats`]).
    pub fault: FaultStats,
    /// Cross-shard atomics-journal counters ([`HetGpu::journal_stats`]).
    pub journal: JournalStats,
    /// Static-analyzer counters ([`HetGpu::analysis_stats`]).
    pub analysis: AnalysisStats,
    /// Event-graph lifecycle counters ([`HetGpu::graph_stats`]).
    pub graph: GraphStats,
    /// Per-device dirty-tracking counters ([`HetGpu::dirty_stats`]),
    /// indexed by device id.
    pub dirty: Vec<DirtyStats>,
    /// Per-phase latency distributions (count, total, p50/p90/p99 µs) of
    /// the launch lifecycle, one entry per [`Phase`] in `Phase::ALL`
    /// order. Populated while tracing is armed.
    pub phases: Vec<PhaseStats>,
    /// Per-kernel execution profiles keyed by `(module uid, kernel,
    /// device kind, JIT tier)`, harvested from the simulators' cost
    /// reports while tracing is armed.
    pub profiles: Vec<(ProfileKey, KernelProfile)>,
    /// Flight-recorder spans evicted (drop-oldest) since arming.
    pub spans_dropped: u64,
}

impl HetGpu {
    /// Create a context with the given simulated devices. Each device's
    /// block-dispatch worker count comes from `HETGPU_SIM_THREADS`
    /// (default: host cores).
    pub fn with_devices(kinds: &[DeviceKind]) -> Result<HetGpu> {
        HetGpu::build(kinds, None, None, None)
    }

    /// Create a context with an explicit per-device dispatch worker count
    /// (overrides `HETGPU_SIM_THREADS`; `1` forces sequential block
    /// execution).
    pub fn with_devices_and_workers(kinds: &[DeviceKind], workers: usize) -> Result<HetGpu> {
        HetGpu::build(kinds, Some(workers), None, None)
    }

    /// Create a context with explicit workers AND an explicit JIT tiering
    /// policy (overrides `HETGPU_JIT_HOT_THRESHOLD` / `HETGPU_JIT_TIER` —
    /// tests pin policies without racing on process-global env vars).
    pub fn with_devices_workers_and_jit(
        kinds: &[DeviceKind],
        workers: usize,
        jit: TierPolicy,
    ) -> Result<HetGpu> {
        HetGpu::build(kinds, Some(workers), Some(jit), None)
    }

    /// Create a context with an explicit on-disk translation-cache
    /// location (overrides `HETGPU_CACHE_DIR` / `HETGPU_CACHE_MAX_MB` —
    /// tests pin cache dirs without racing on process-global env vars).
    pub fn with_devices_workers_jit_and_cache(
        kinds: &[DeviceKind],
        workers: usize,
        jit: TierPolicy,
        cache: DiskCacheConfig,
    ) -> Result<HetGpu> {
        HetGpu::build(kinds, Some(workers), Some(jit), Some(cache))
    }

    fn build(
        kinds: &[DeviceKind],
        workers: Option<usize>,
        jit: Option<TierPolicy>,
        cache: Option<DiskCacheConfig>,
    ) -> Result<HetGpu> {
        if kinds.is_empty() {
            return Err(HetError::runtime("no devices"));
        }
        let devices: Vec<Device> = kinds
            .iter()
            .enumerate()
            .map(|(i, k)| match workers {
                Some(w) => Device::new_with_workers(i, *k, w),
                None => Device::new(i, *k),
            })
            .collect();
        // Arm the fault plane from the environment (inert when unset; a
        // malformed value warns once and is ignored).
        let fault = FaultInjector::default();
        if let Some(plan) = FaultPlan::from_env() {
            fault.install(plan);
        }
        let jit_policy = jit.unwrap_or_else(TierPolicy::from_env);
        // The on-disk translation cache: an explicit config wins; else the
        // `HETGPU_CACHE_DIR` env contract; else disabled. An explicit dir
        // that can't be created is a hard error (the caller asked for it);
        // env-configured dirs degrade to no-cache with a warning.
        let disk = match cache {
            Some(cfg) => Some(DiskCache::new(cfg).map_err(|e| {
                HetError::runtime(format!("translation cache dir unusable: {e}"))
            })?),
            None => DiskCache::from_env(),
        };
        let inner = Arc::new(RuntimeInner {
            devices,
            modules: std::sync::RwLock::new(ModuleTable::new()),
            jit: JitCache::with_policy_and_disk(jit_policy, disk),
            memory: MemoryManager::new(crate::runtime::device::DEVICE_MEM_BYTES),
            fault,
            // Observability plane: disarmed unless `HETGPU_TRACE` asked
            // for a dump-on-drop trace (DESIGN.md §13).
            obs: crate::obs::Obs::from_env(),
        });
        let graph = EventGraph::new(inner.clone());
        // Enough executors that every device can be mid-launch while a few
        // extra streams overlap copies; executors block while a node runs.
        let executors = EventGraph::spawn_executors(&graph, (kinds.len() * 2).clamp(2, 8));
        // The background tier-2 compiler: parked on the hot queue unless a
        // forced tier disables adaptive promotion entirely.
        let jit_compiler = if jit_policy.force.is_none() {
            let rt = inner.clone();
            Some(
                std::thread::Builder::new()
                    .name("hetgpu-jit2".into())
                    .spawn(move || crate::runtime::jit_compiler_loop(rt))
                    .map_err(|e| HetError::runtime(format!("spawn jit compiler: {e}")))?,
            )
        } else {
            None
        };
        Ok(HetGpu {
            inner,
            graph,
            executors,
            jit_compiler,
            coord: Mutex::new(CoordCache::default()),
            journal_counters: JournalCounters::default(),
            analysis_default: AnalysisLevel::from_env(),
            analysis_counters: AnalysisCounters::default(),
        })
    }

    /// Create a context with all four paper devices.
    pub fn full_testbed() -> Result<HetGpu> {
        HetGpu::with_devices(&DeviceKind::all())
    }

    /// Dispatch worker threads device `id` spreads thread blocks over.
    pub fn sim_workers(&self, id: usize) -> Result<usize> {
        Ok(self.inner.device(id)?.engine.workers())
    }

    pub fn device_count(&self) -> usize {
        self.inner.devices.len()
    }

    pub fn device_kind(&self, id: usize) -> Result<DeviceKind> {
        Ok(self.inner.device(id)?.kind)
    }

    /// Shared runtime internals (benches/tests poke at the JIT cache).
    pub fn runtime(&self) -> &RuntimeInner {
        &self.inner
    }

    /// The command DAG (crate-internal: coordinator + tests).
    pub(crate) fn graph(&self) -> &Arc<EventGraph> {
        &self.graph
    }

    /// Multi-device coordinator view of this context (paper §4.3/§6.3
    /// L3 coordination): shard one grid over several devices, rebalance
    /// paused shards.
    pub fn coordinator(&self) -> Coordinator<'_> {
        Coordinator::new(self)
    }

    // ---- modules ----

    /// Compile CUDA-subset source into a loaded module.
    pub fn compile_cuda(&self, src: &str) -> Result<ModuleHandle> {
        let module = frontend::compile(src, "cuda-module")?;
        self.load_module(module)
    }

    /// Load a hetIR module from its text-assembly form ("the binary").
    pub fn load_module_text(&self, text: &str) -> Result<ModuleHandle> {
        let module = hetir::parser::parse_module(text)?;
        self.load_module(module)
    }

    /// Load an in-memory hetIR module (verifies every kernel first, then
    /// runs the static analyzer — unless the context default is
    /// [`AnalysisLevel::Off`], in which case analysis happens lazily on
    /// the first launch that asks for it). The report is cached beside
    /// the module, so analysis runs once per `(module, kernel)` no matter
    /// how many launches follow.
    pub fn load_module(&self, module: Module) -> Result<ModuleHandle> {
        hetir::verify::verify_module(&module)?;
        let report = if self.analysis_default != AnalysisLevel::Off {
            Some(Arc::new(self.run_analysis(&module)))
        } else {
            None
        };
        let mut modules = self.inner.modules.write().unwrap();
        let h = modules.insert(module);
        if let Some(r) = report {
            // The handle was minted under this same write lock, so the
            // cache write cannot miss.
            let _ = modules.set_analysis(h, r);
        }
        Ok(h)
    }

    /// Pre-lower every kernel of a loaded module for every backend ISA at
    /// both JIT tiers and pack the versioned fat-blob artifact
    /// (DESIGN.md §14) — the AOT half of the zero-translation warm start.
    /// Feed the bytes back to [`HetGpu::load_fat_blob`] (any process, any
    /// machine with the same codec version).
    pub fn build_fat_blob(&self, module: ModuleHandle) -> Result<Vec<u8>> {
        let modules = self.inner.modules.read().unwrap();
        let (m, _uid) = modules.get(module)?;
        aot::build_fat_blob(m)
    }

    /// Load a module from a fat-blob artifact: parse the embedded hetIR
    /// (always — the portable fallback), then seed the JIT cache with
    /// every pre-lowered entry that survives validation, so first
    /// launches on every backend start at tier 2 with **zero**
    /// translation work ([`JitStats::aot_seeded`] counts the seeds).
    ///
    /// Degradation is silent and per-entry: a stale codec version, a
    /// corrupt entry, or an unknown target skips that entry and the
    /// runtime JITs from the embedded IR as if the blob were plain text.
    pub fn load_fat_blob(&self, bytes: &[u8]) -> Result<ModuleHandle> {
        let blob = aot::parse_fat_blob(bytes)?;
        let h = self.load_module(blob.module)?;
        if !blob.entries.is_empty() {
            let uid = {
                let modules = self.inner.modules.read().unwrap();
                let (_m, uid) = modules.get(h)?;
                uid
            };
            self.inner.jit.seed_aot(uid, blob.entries);
        }
        Ok(h)
    }

    /// On-disk translation-cache counters (hits, misses, stores,
    /// evictions, resident bytes). All zeros when no cache is configured.
    pub fn cache_stats(&self) -> CacheStats {
        self.inner.jit.disk_stats().unwrap_or_default()
    }

    /// The static-analysis report for a loaded module, computing and
    /// caching it on first use (module load already computed it unless
    /// the context default is `Off`). Repeated calls return the same
    /// `Arc` — analysis never reruns for a loaded module.
    pub fn analysis_report(&self, module: ModuleHandle) -> Result<Arc<AnalysisReport>> {
        if let Some(r) = self.inner.modules.read().unwrap().analysis(module)? {
            return Ok(r);
        }
        let report = {
            let modules = self.inner.modules.read().unwrap();
            let (m, _uid) = modules.get(module)?;
            Arc::new(self.run_analysis(m))
        };
        let mut modules = self.inner.modules.write().unwrap();
        if let Some(r) = modules.analysis(module)? {
            return Ok(r); // a racing caller computed and cached it first
        }
        modules.set_analysis(module, Arc::clone(&report))?;
        Ok(report)
    }

    /// Run the analyzer over a module: bump the context counters and
    /// print `Warning`-and-above diagnostics to stderr (the `Warn`-mode
    /// contract; `Strict` additionally gates launches in `preflight`).
    fn run_analysis(&self, module: &Module) -> AnalysisReport {
        let report = analyze::analyze_module(module);
        let (info, warn, err) = report.diag_counts();
        let c = &self.analysis_counters;
        c.kernels_analyzed.fetch_add(report.kernels.len() as u64, Ordering::Relaxed);
        c.diags_info.fetch_add(info, Ordering::Relaxed);
        c.diags_warning.fetch_add(warn, Ordering::Relaxed);
        c.diags_error.fetch_add(err, Ordering::Relaxed);
        c.analysis_nanos.fetch_add(report.total_nanos(), Ordering::Relaxed);
        for kr in &report.kernels {
            for d in &kr.diags {
                if d.severity >= Severity::Warning {
                    eprintln!("{d}");
                }
            }
        }
        report
    }

    /// Launch pre-flight (DESIGN.md §12): gate `spec` against the cached
    /// analysis report at `level`, before anything is recorded into the
    /// event graph. `Strict` rejects kernels carrying any load-time
    /// `Warning`-or-above diagnostic; at **both** `Strict` and `Warn` the
    /// recorded access forms are instantiated against the concrete
    /// dims/args and a *provable* out-of-bounds access fails the launch —
    /// there is no configuration in which running it is correct.
    pub(crate) fn preflight(&self, spec: &LaunchSpec, level: AnalysisLevel) -> Result<()> {
        if level == AnalysisLevel::Off {
            return Ok(());
        }
        self.analysis_counters.preflight_checks.fetch_add(1, Ordering::Relaxed);
        let report = self.analysis_report(spec.module)?;
        let Some(kr) = report.kernel(&spec.kernel) else {
            return Ok(()); // unknown kernels fail with their own error downstream
        };
        if level == AnalysisLevel::Strict {
            if let Some(d) = kr.diags.iter().find(|d| d.severity >= Severity::Warning) {
                self.analysis_counters.preflight_rejections.fetch_add(1, Ordering::Relaxed);
                return Err(HetError::static_fault(
                    &kr.name,
                    d.path.to_string(),
                    d.to_string(),
                ));
            }
        }
        let (param_vals, param_avail) = self.resolve_preflight_args(spec);
        let res = {
            let modules = self.inner.modules.read().unwrap();
            let (m, _uid) = modules.get(spec.module)?;
            match m.kernel(&spec.kernel) {
                Some(k) => analyze::preflight_launch(
                    kr,
                    k,
                    spec.dims.grid,
                    spec.dims.block,
                    &param_vals,
                    &param_avail,
                ),
                None => Ok(()),
            }
        };
        if res.is_err() {
            self.analysis_counters.preflight_rejections.fetch_add(1, Ordering::Relaxed);
        }
        res
    }

    /// Resolve launch args for bounds instantiation: scalar args become
    /// concrete values; pointer args resolve to the byte count available
    /// from the pointer to the end of its allocation (`None` when the
    /// pointer does not land in a live allocation — pre-flight then skips
    /// accesses through it and leaves them to the device fault path).
    fn resolve_preflight_args(&self, spec: &LaunchSpec) -> (Vec<Option<i128>>, Vec<Option<i128>>) {
        let mut vals = Vec::with_capacity(spec.args.len());
        let mut avail = Vec::with_capacity(spec.args.len());
        for a in &spec.args {
            let (v, n) = match a {
                Arg::Ptr(p) => (
                    None,
                    self.inner.memory.lookup(*p).ok().and_then(|(base, size, _dev)| {
                        let off = p.0.checked_sub(base)?;
                        size.checked_sub(off).map(|left| left as i128)
                    }),
                ),
                Arg::U32(v) => (Some(*v as i128), None),
                Arg::I32(v) => (Some(*v as i128), None),
                Arg::U64(v) => (Some(*v as i128), None),
                Arg::I64(v) => (Some(*v as i128), None),
                Arg::F32(_) => (None, None),
                Arg::Pred(v) => (Some(*v as i128), None),
            };
            vals.push(v);
            avail.push(n);
        }
        (vals, avail)
    }

    /// Context-lifetime static-analyzer counters: kernels analyzed,
    /// diagnostics by severity, launch pre-flights, and static launch
    /// rejections (see [`AnalysisStats`]). Also folded into
    /// [`HetGpu::metrics`].
    pub fn analysis_stats(&self) -> AnalysisStats {
        let c = &self.analysis_counters;
        AnalysisStats {
            kernels_analyzed: c.kernels_analyzed.load(Ordering::Relaxed),
            diags_info: c.diags_info.load(Ordering::Relaxed),
            diags_warning: c.diags_warning.load(Ordering::Relaxed),
            diags_error: c.diags_error.load(Ordering::Relaxed),
            preflight_checks: c.preflight_checks.load(Ordering::Relaxed),
            preflight_rejections: c.preflight_rejections.load(Ordering::Relaxed),
            analysis_nanos: c.analysis_nanos.load(Ordering::Relaxed),
        }
    }

    /// Unload a module: frees its IR, evicts its cached translations, and
    /// stales its handle. Launches already queued against it fail with a
    /// typed stale-handle error when the executor reaches them.
    pub fn unload_module(&self, module: ModuleHandle) -> Result<()> {
        let uid = self.inner.modules.write().unwrap().remove(module)?;
        self.inner.jit.evict_module(uid);
        Ok(())
    }

    // ---- raw memory (pointer surface) ----

    /// Allocate device memory resident on `device` (raw pointer surface;
    /// prefer [`HetGpu::alloc_buffer`] for typed, staleness-checked I/O).
    pub fn malloc_on(&self, bytes: u64, device: usize) -> Result<GpuPtr> {
        self.inner.device(device)?;
        self.inner.memory.alloc(bytes, device)
    }

    /// Free a raw allocation. Typed buffer handles minted for the same
    /// allocation become stale.
    pub fn free(&self, ptr: GpuPtr) -> Result<()> {
        self.inner.memory.free(ptr)
    }

    /// Host→device copy (to wherever the buffer is resident). Synchronous
    /// and kernel-ordered: takes the device gate exclusively, so it waits
    /// for in-flight launches on the device rather than racing them; use
    /// [`HetGpu::memcpy_h2d_async`] for a stream-ordered copy that
    /// overlaps other streams' kernels.
    pub fn memcpy_h2d(&self, dst: GpuPtr, data: &[u8]) -> Result<()> {
        let (base, size, device) = self.inner.memory.lookup(dst)?;
        if copy_end(dst.0, data.len() as u64, "h2d")? > base.saturating_add(size) {
            return Err(HetError::runtime("h2d copy out of bounds"));
        }
        let dev = self.inner.device(device)?;
        let _gate = dev.exec.write().unwrap();
        dev.mem.write_bytes(dst.0, data)
    }

    /// Device→host copy. Synchronous and kernel-ordered (see
    /// [`HetGpu::memcpy_h2d`]): waits for in-flight launches on the
    /// device, so it never reads a half-written image.
    pub fn memcpy_d2h(&self, out: &mut [u8], src: GpuPtr) -> Result<()> {
        let (base, size, device) = self.inner.memory.lookup(src)?;
        if copy_end(src.0, out.len() as u64, "d2h")? > base.saturating_add(size) {
            return Err(HetError::runtime("d2h copy out of bounds"));
        }
        let dev = self.inner.device(device)?;
        let _gate = dev.exec.write().unwrap();
        dev.mem.read_bytes_into(src.0, out)
    }

    // ---- typed buffers (unified copy surface) ----

    /// Allocate a typed device buffer of `len` elements on `device`.
    pub fn alloc_buffer<T: Pod>(&self, len: usize, device: usize) -> Result<Buffer<T>> {
        self.inner.device(device)?;
        let bytes = (len as u64)
            .checked_mul(T::SIZE as u64)
            .ok_or_else(|| HetError::runtime("buffer byte size overflows u64"))?;
        let (ptr, slot, gen) = self.inner.memory.alloc_handle(bytes, device)?;
        Ok(Buffer::new(slot, gen, ptr, len))
    }

    /// Free a typed buffer; the handle (and every copy of it) goes stale.
    /// Validation and release are one critical section, so racing frees
    /// of copied handles cannot free an allocation that reused the range.
    pub fn free_buffer<T: Pod>(&self, buf: &Buffer<T>) -> Result<()> {
        self.inner.memory.free_by_handle(buf.slot, buf.gen)
    }

    /// Upload typed elements into a buffer (synchronous, kernel-ordered).
    /// The handle is revalidated: freed or stale buffers fail with
    /// `HetError::InvalidHandle`, writes beyond `buf.len()` fail closed.
    pub fn upload<T: Pod>(&self, buf: &Buffer<T>, data: &[T]) -> Result<()> {
        let (_base, _size, device) = self.inner.memory.resolve(buf.slot, buf.gen)?;
        if data.len() > buf.len() {
            return Err(HetError::runtime(format!(
                "upload of {} elements exceeds buffer length {}",
                data.len(),
                buf.len()
            )));
        }
        let bytes = pod_to_bytes(data);
        let dev = self.inner.device(device)?;
        let _gate = dev.exec.write().unwrap();
        // Re-resolve under the device gate: a free + realloc that won the
        // race between validation and the gate stales the handle here
        // instead of the copy landing in whatever reused the range.
        let (base, _size2, device2) = self.inner.memory.resolve(buf.slot, buf.gen)?;
        if device2 != device {
            return Err(HetError::runtime("buffer migrated concurrently during upload"));
        }
        dev.mem.write_bytes(base, &bytes)
    }

    /// Download the first `n` typed elements of a buffer (synchronous,
    /// kernel-ordered). Stale handles and over-long reads fail closed.
    pub fn download<T: Pod>(&self, buf: &Buffer<T>, n: usize) -> Result<Vec<T>> {
        let (_base, _size, device) = self.inner.memory.resolve(buf.slot, buf.gen)?;
        if n > buf.len() {
            return Err(HetError::runtime(format!(
                "download of {n} elements exceeds buffer length {}",
                buf.len()
            )));
        }
        let mut bytes = vec![0u8; n * T::SIZE];
        {
            let dev = self.inner.device(device)?;
            let _gate = dev.exec.write().unwrap();
            // Re-resolve under the gate (see `upload`): stale-by-race
            // handles fail instead of reading a reused range.
            let (base, _size2, device2) = self.inner.memory.resolve(buf.slot, buf.gen)?;
            if device2 != device {
                return Err(HetError::runtime("buffer migrated concurrently during download"));
            }
            dev.mem.read_bytes_into(base, &mut bytes)?;
        }
        Ok(pod_from_bytes(&bytes))
    }

    // ---- streams ----

    /// Create a stream bound to `device`. Streams are thin graph handles —
    /// creating one spawns no thread; the graph is the single source of
    /// stream identity. Quarantined devices refuse new streams (execution
    /// placement is gated; their memory stays readable) until a
    /// [`HetGpu::probe_device`] reinstates them.
    pub fn create_stream(&self, device: usize) -> Result<StreamHandle> {
        let dev = self.inner.device(device)?;
        if dev.health() == HealthState::Quarantined {
            return Err(HetError::runtime(format!(
                "device {device} ({}) is quarantined after a fault; probe_device to reinstate",
                dev.kind.name()
            )));
        }
        Ok(self.graph.add_stream(device))
    }

    /// Destroy a stream: waits for its queued work to drain (a poisoned
    /// stream's cleared queue counts as drained), retires its events, and
    /// frees its slot for reuse. Destroying a stream halted at a
    /// checkpoint is an error — resume it first. Double-destroys and
    /// stale handles fail with `HetError::InvalidHandle`.
    pub fn destroy_stream(&self, stream: StreamHandle) -> Result<()> {
        self.graph.destroy_stream(stream)
    }

    /// Which device a stream currently runs on.
    pub fn stream_device(&self, s: StreamHandle) -> Result<usize> {
        self.graph.stream_device(s)
    }

    // ---- launch ----

    /// Start describing a kernel launch from `module`. Finish the builder
    /// with [`LaunchBuilder::record`] (one stream) or
    /// [`LaunchBuilder::sharded`] (coordinator grid split).
    ///
    /// ```ignore
    /// let ev = ctx.launch(module, "saxpy")
    ///     .dims(LaunchDims::d1(256, 256))
    ///     .arg(&x).arg(&y).arg(2.0f32).arg(n as u32)
    ///     .record(stream)?;
    /// ```
    pub fn launch(&self, module: ModuleHandle, kernel: &str) -> LaunchBuilder<'_> {
        LaunchBuilder {
            ctx: self,
            module,
            kernel: kernel.to_string(),
            dims: None,
            args: Vec::new(),
            tensix_mode: None,
            working_set: None,
            atomics: AtomicsMode::default(),
            fault_policy: FaultPolicy::default(),
            analysis: None,
        }
    }

    /// Record a fully-built launch spec on a stream (crate-internal; the
    /// coordinator also enters here for shard launches, with the block
    /// `range` it owns, the broadcast events it must wait for, and the
    /// shard's atomics `journal` when the launch runs the cross-shard
    /// journal protocol). `trace` is the launch's observability root span
    /// id (0 when tracing is disarmed) — the executor parents its
    /// graph-schedule/dispatch spans under it.
    pub(crate) fn record_launch(
        &self,
        stream: StreamHandle,
        spec: LaunchSpec,
        shard: Option<ShardRange>,
        deps: &[EventId],
        journal: Option<Arc<AtomicJournal>>,
        trace: u64,
    ) -> Result<EventId> {
        // Fail stale module handles at record time (the executor
        // re-checks at execution, when the table may have changed).
        self.inner.modules.read().unwrap().get(spec.module)?;
        self.graph.enqueue(stream, NodeKind::Launch { spec, shard, journal, trace }, deps)
    }

    /// Record a batch of launches on `stream` in **one** event-graph
    /// submission — the last launch-batching rung after the per-stream
    /// JIT memo: every launch is pre-flighted up front, then all nodes
    /// enter the graph under a single graph lock with a single executor
    /// wake-up, instead of paying one lock hand-off + condvar notify per
    /// launch. Returns the launches' events in record order; stream
    /// ordering within the batch is unchanged (they run in order, like N
    /// separate `record` calls). Every builder must come from this
    /// context, and any failure (bad spec, pre-flight rejection) records
    /// nothing.
    pub fn record_batch(
        &self,
        stream: StreamHandle,
        launches: Vec<LaunchBuilder<'_>>,
    ) -> Result<Vec<EventId>> {
        let obs = &self.inner.obs;
        let root = obs.begin();
        let trace = root.map_or(0, |s| s.id);
        let n = launches.len();
        let build = || -> Result<Vec<NodeKind>> {
            let mut kinds = Vec::with_capacity(n);
            for b in launches {
                if !std::ptr::eq(b.ctx, self) {
                    return Err(HetError::runtime(
                        "record_batch: launch was built on a different context",
                    ));
                }
                let (_ctx, spec, _ws, _atomics, _policy, level) = b.build_spec()?;
                let a_span = obs.begin();
                let pf = self.preflight(&spec, level);
                if let Some(s) = a_span {
                    obs.end(s, trace, Phase::Analyze, &spec.kernel, None);
                }
                pf?;
                self.inner.modules.read().unwrap().get(spec.module)?;
                kinds.push(NodeKind::Launch { spec, shard: None, journal: None, trace });
            }
            Ok(kinds)
        };
        let out = build().and_then(|kinds| self.graph.enqueue_batch(stream, kinds));
        if let Some(s) = root {
            obs.end(s, 0, Phase::Record, &format!("batch record ({n} launches)"), None);
        }
        out
    }

    // ---- events ----

    /// Record a marker event on a stream (the analog of
    /// `cudaEventRecord`): completes when everything previously recorded
    /// on the stream has completed.
    pub fn record_event(&self, stream: StreamHandle) -> Result<EventId> {
        self.graph.enqueue(stream, NodeKind::Marker, &[])
    }

    /// Make `stream` wait for `event` (recorded on any stream) before
    /// running its subsequent commands — a cross-stream DAG edge. Waiting
    /// on a retired event is a stale-handle error.
    pub fn wait_event(&self, stream: StreamHandle, event: EventId) -> Result<EventId> {
        self.graph.enqueue(stream, NodeKind::Marker, &[event])
    }

    /// Status of a recorded event (stale handles fail with
    /// `HetError::InvalidHandle`).
    pub fn event_query(&self, event: EventId) -> Result<EventStatus> {
        self.graph.query(event)
    }

    /// Drop the caller's hold on an event so its terminal status can be
    /// reclaimed (it stays tracked only while pending nodes depend on
    /// it). Destroying a stream retires its events in bulk.
    pub fn retire_event(&self, event: EventId) -> Result<()> {
        self.graph.retire_event(event)
    }

    /// Live/allocated handle counts of the event graph — the lifecycle
    /// observability hook: slot counts are bounded by peak concurrent
    /// liveness, not total history. Also folded into
    /// [`HetGpu::metrics`].
    pub fn graph_stats(&self) -> GraphStats {
        self.graph.graph_stats()
    }

    /// Context-lifetime counters of the cross-shard atomics protocol:
    /// how many sharded launches ran journaled, journal ops replayed at
    /// joins, entries shipped through rebalance blobs. Per-launch
    /// accounting is in `ShardReport::io`; also folded into
    /// [`HetGpu::metrics`].
    pub fn journal_stats(&self) -> JournalStats {
        JournalStats {
            journaled_launches: self.journal_counters.journaled_launches.load(Ordering::Relaxed),
            ops_replayed: self.journal_counters.ops_replayed.load(Ordering::Relaxed),
            entries_shipped: self.journal_counters.entries_shipped.load(Ordering::Relaxed),
        }
    }

    // ---- fault plane (injection, health, recovery observability) ----

    /// Install (or replace) a deterministic fault plan on this context
    /// (see [`FaultPlan::parse`] for the `HETGPU_FAULT_PLAN` grammar,
    /// which is also read automatically at context creation). Operation
    /// ordinals (`nth`) count from installation.
    pub fn install_fault_plan(&self, plan: FaultPlan) {
        self.inner.fault.install(plan);
    }

    /// Context-lifetime fault-plane counters: faults injected by the
    /// plan, device faults observed by the executor (injected or
    /// organic), retry attempts, recovered shards, and quarantines. Also
    /// folded into [`HetGpu::metrics`].
    pub fn fault_stats(&self) -> FaultStats {
        self.inner.fault.stats()
    }

    /// Tiered-JIT observability: cache hits, per-tier translation counts,
    /// background promotions, in-flight compiles, installed swaps, the
    /// current cache generation, and dropped ring events (DESIGN.md §11).
    /// Also folded into [`HetGpu::metrics`].
    pub fn jit_stats(&self) -> JitStats {
        self.inner.jit.stats()
    }

    /// Current operational health of `device`.
    pub fn device_health(&self, device: usize) -> Result<HealthState> {
        Ok(self.inner.device(device)?.health())
    }

    /// Move `device` to `Quarantined` (idempotent), excluding it from
    /// stream creation and shard placement. Crate-internal: fault
    /// policies quarantine; users reinstate via `probe_device`.
    pub(crate) fn quarantine_device(&self, device: usize) {
        if let Ok(d) = self.inner.device(device) {
            if d.health() != HealthState::Quarantined {
                d.set_health(HealthState::Quarantined);
                self.inner.fault.counters.quarantines.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Probe a (possibly quarantined) device: run a small self-test
    /// kernel directly on the engine — bypassing the quarantine gate and
    /// the fault plan's launch hook, so the probe measures the device,
    /// not the armed plan — and verify its output. Returns `true` and
    /// reinstates the device to `Healthy` on success; returns `false`
    /// (health unchanged) when the probe faults or miscomputes.
    pub fn probe_device(&self, device: usize) -> Result<bool> {
        self.inner.device(device)?;
        let m = self.compile_cuda(
            r#"__global__ void hetgpu_probe(unsigned* p) {
                p[threadIdx.x] = threadIdx.x * 2654435761u + 12345u;
            }"#,
        )?;
        let buf = self.alloc_buffer::<u32>(32, device)?;
        let spec = LaunchSpec {
            module: m,
            kernel: "hetgpu_probe".to_string(),
            dims: LaunchDims::d1(1, 32),
            args: vec![Arg::Ptr(buf.ptr())],
            tensix_mode_hint: None,
        };
        let run = self.inner.run_launch(device, &spec, None, None, None, None, None, 0);
        let passed = match run {
            Ok(_) => self
                .download(&buf, 32)?
                .iter()
                .enumerate()
                .all(|(i, &v)| v == (i as u32).wrapping_mul(2654435761).wrapping_add(12345)),
            Err(e) if e.is_device_fault() => false,
            Err(e) => {
                let _ = self.free_buffer(&buf);
                let _ = self.unload_module(m);
                return Err(e);
            }
        };
        let _ = self.free_buffer(&buf);
        let _ = self.unload_module(m);
        if passed {
            self.inner.device(device)?.set_health(HealthState::Healthy);
        }
        Ok(passed)
    }

    // ---- async copies (event-graph nodes) ----

    /// Asynchronous host→device copy, ordered with the stream's other
    /// commands (the event-graph analog of `cudaMemcpyAsync`).
    pub fn memcpy_h2d_async(
        &self,
        stream: StreamHandle,
        dst: GpuPtr,
        data: &[u8],
    ) -> Result<EventId> {
        // Fail unknown pointers and overruns at record time, like the
        // synchronous path (the executor re-checks at execution, when the
        // allocation table may have changed).
        let (base, size, _device) = self.inner.memory.lookup(dst)?;
        if copy_end(dst.0, data.len() as u64, "h2d")? > base.saturating_add(size) {
            return Err(HetError::runtime("h2d copy out of bounds"));
        }
        self.graph.enqueue(stream, NodeKind::CopyH2D { dst, data: data.to_vec() }, &[])
    }

    /// Asynchronous device→host copy into a pinned host buffer, ordered
    /// with the stream's other commands. Reads the *stream's* device
    /// arena (a coordinator shard's stream is bound to the device holding
    /// the shard image, including after a rebalance); the buffer holds
    /// the bytes once the returned event completes.
    pub fn memcpy_d2h_async(
        &self,
        stream: StreamHandle,
        dst: &PinnedBuffer,
        src: GpuPtr,
    ) -> Result<EventId> {
        let (base, size, _device) = self.inner.memory.lookup(src)?;
        if copy_end(src.0, dst.len() as u64, "d2h")? > base.saturating_add(size) {
            return Err(HetError::runtime("d2h copy out of bounds"));
        }
        self.graph.enqueue(stream, NodeKind::CopyD2H { src, dst: dst.clone() }, &[])
    }

    /// Asynchronous peer copy: pull `bytes` bytes at `ptr` from
    /// `src_device`'s arena into the arena of the device this stream runs
    /// on (same unified virtual address on both sides — no pointer
    /// fix-up). The coordinator uses this to broadcast memory images to
    /// shard devices without staging through the host.
    pub fn memcpy_peer_async(
        &self,
        stream: StreamHandle,
        ptr: GpuPtr,
        bytes: u64,
        src_device: usize,
    ) -> Result<EventId> {
        self.inner.device(src_device)?;
        let (base, size, _device) = self.inner.memory.lookup(ptr)?;
        if copy_end(ptr.0, bytes, "peer")? > base.saturating_add(size) {
            return Err(HetError::runtime("peer copy out of bounds"));
        }
        self.graph.enqueue(stream, NodeKind::CopyPeer { ptr, bytes, src_device }, &[])
    }

    /// Wait for all work on a stream (propagates sticky errors).
    pub fn synchronize(&self, stream: StreamHandle) -> Result<()> {
        self.graph.synchronize(stream)
    }

    /// Per-stream stats (launches, model cycles, busy and queued wall
    /// time), including the per-device breakdown for streams that
    /// executed on several devices. Context-wide planes are folded into
    /// [`HetGpu::metrics`].
    pub fn stream_stats(&self, stream: StreamHandle) -> Result<StreamStats> {
        self.graph.stats(stream)
    }

    // ---- observability plane (DESIGN.md §13) ----

    /// One unified snapshot of every counter plane: the six legacy
    /// `*_stats()` structs plus the observability plane's per-phase
    /// latency histograms, per-kernel execution profiles, and the flight
    /// recorder's drop counter. See [`Metrics`].
    pub fn metrics(&self) -> Metrics {
        Metrics {
            jit: self.jit_stats(),
            cache: self.cache_stats(),
            fault: self.fault_stats(),
            journal: self.journal_stats(),
            analysis: self.analysis_stats(),
            graph: self.graph_stats(),
            dirty: (0..self.device_count()).filter_map(|d| self.dirty_stats(d).ok()).collect(),
            phases: self.inner.obs.phase_stats(),
            profiles: self.inner.obs.profiles(),
            spans_dropped: self.inner.obs.dropped(),
        }
    }

    /// Arm the tracing plane: launches start emitting lifecycle span
    /// trees into the flight recorder and the simulators' cost reports
    /// are harvested into per-kernel profiles. While disarmed, every
    /// instrumentation site costs exactly one relaxed atomic load.
    /// `HETGPU_TRACE=<path>` arms at context creation and additionally
    /// exports the recorder on drop.
    pub fn arm_tracing(&self) {
        self.inner.obs.arm();
    }

    /// Disarm the tracing plane (recorded spans, histograms, and
    /// profiles are kept; new launches stop emitting).
    pub fn disarm_tracing(&self) {
        self.inner.obs.disarm();
    }

    /// Whether the tracing plane is currently armed.
    pub fn tracing_armed(&self) -> bool {
        self.inner.obs.armed()
    }

    /// The flight recorder's current contents, oldest first — the
    /// bounded span ring behind [`HetGpu::export_trace`] (capacity from
    /// `HETGPU_TRACE_RING`, drop-oldest; evictions are counted in
    /// [`Metrics::spans_dropped`]).
    pub fn trace_spans(&self) -> Vec<SpanEvent> {
        self.inner.obs.spans()
    }

    /// Export the flight recorder as a Chrome trace-event JSON file that
    /// Perfetto / `chrome://tracing` load directly: one track per device
    /// plus a host "runtime" track, spans nested by trace-tree parent
    /// ids carried in `args`.
    pub fn export_trace(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        self.inner.obs.export_trace(path.as_ref(), &self.device_track_names())
    }

    fn device_track_names(&self) -> Vec<String> {
        self.inner
            .devices
            .iter()
            .map(|d| format!("dev{} {}", d.id, d.kind.name()))
            .collect()
    }

    // ---- checkpoint / migration (paper §4.2, §6.3) ----

    /// Cooperatively checkpoint a stream: sets the device pause flag,
    /// waits for the in-flight kernel to dump at its next barrier (or
    /// finish), and returns the device-neutral snapshot (kernel state +
    /// all global allocations on the device). The snapshot names the
    /// stream it was taken from by handle, so [`HetGpu::restore`] needs no
    /// separate stream argument.
    ///
    /// Capture is **streamed** (delta-state engine): the memory image is
    /// copied through chunked event-graph nodes into pinned staging under
    /// the shared device gate, with dirty-epoch consistency repair —
    /// other streams on the device keep executing instead of sitting
    /// behind one stop-the-world exclusive copy. The returned snapshot
    /// records the capture epoch, the base a later
    /// [`HetGpu::snapshot_incremental`] diffs against.
    pub fn checkpoint(&self, stream: StreamHandle) -> Result<Snapshot> {
        let obs_span = self.inner.obs.begin();
        let (device, paused) = self.pause_and_harvest(stream)?;
        let epoch = self.inner.device(device)?.mem.dirty_epoch_cut();
        let spans = self.inner.memory.allocations_on(device);
        let captured = capture_spans(self, device, &spans, epoch, &spans);
        // Launches of *other* streams overlapping on this device may also
        // have observed the pause flag and halted; resume them in place so
        // a checkpoint of one stream never silently strands its neighbors.
        self.graph.resume_collateral(device, stream);
        let allocations = captured?;
        if let Some(s) = obs_span {
            self.inner.obs.end(s, 0, Phase::DeltaCapture, "checkpoint", Some(device));
        }
        Ok(Snapshot {
            stream,
            src_device: device,
            paused,
            allocations,
            shard: None,
            epoch,
            base_epoch: None,
            journal: Vec::new(),
        })
    }

    /// Capture an **incremental snapshot**: the same checkpoint protocol
    /// as [`HetGpu::checkpoint`], but the memory payload holds only the
    /// page runs dirtied since `base` was captured — O(dirty pages)
    /// instead of O(all allocations). Restore by overlaying onto the
    /// base ([`Snapshot::apply_delta`], which fails closed on an epoch
    /// mismatch) and passing the result to [`HetGpu::restore`].
    ///
    /// Falls back to a full capture (a snapshot with `base_epoch: None`)
    /// when the base cannot anchor a delta: it is itself a delta, came
    /// from a legacy (v2/v3) blob without an epoch, was taken on a
    /// different device than the stream now runs on, or the device's
    /// **allocation set drifted** since the base. Drift makes the pairing
    /// unsound both ways — a span in a base-unknown allocation is
    /// unappliable (late hard error), and a freed-then-reused range would
    /// silently resurrect the base's stale bytes — so it degrades to a
    /// full capture instead.
    pub fn snapshot_incremental(
        &self,
        stream: StreamHandle,
        base: &Snapshot,
    ) -> Result<Snapshot> {
        let obs_span = self.inner.obs.begin();
        let (device, paused) = self.pause_and_harvest(stream)?;
        // Cut BEFORE deriving the delta's spans: a write racing this
        // boundary is then either visible to the `dirty_since(base)`
        // query below (captured by this delta) or lands at an epoch
        // >= `epoch` (captured by the next delta). Deriving spans first
        // would let a racing write to a previously-clean page slip
        // between the two — missing from this delta *and* from every
        // later `dirty_since(epoch)` — silently corrupting base+delta.
        let epoch = self.inner.device(device)?.mem.dirty_epoch_cut();
        let allocs = self.inner.memory.allocations_on(device);
        let same_alloc_set = allocs.len() == base.allocations.len()
            && allocs
                .iter()
                .zip(&base.allocations)
                .all(|(&(a, l), (ba, bb))| a == *ba && l == bb.len() as u64);
        let full_fallback = base.is_delta()
            || base.epoch == 0
            || base.src_device != device
            || !same_alloc_set;
        let (spans, base_epoch) = if full_fallback {
            (allocs.clone(), None)
        } else {
            let dirt = self.inner.device(device)?.mem.dirty_since(base.epoch);
            (crate::delta::capture::clip_runs(&dirt, &allocs), Some(base.epoch))
        };
        // `allocs` is the consistency universe: pages outside the delta's
        // spans dirtied mid-capture are folded in by the final pass, so
        // base+delta is point-in-time like a full checkpoint.
        let captured = capture_spans(self, device, &spans, epoch, &allocs);
        self.graph.resume_collateral(device, stream);
        let allocations = captured?;
        if let Some(s) = obs_span {
            let label =
                if base_epoch.is_some() { "snapshot (delta)" } else { "snapshot (full)" };
            self.inner.obs.end(s, 0, Phase::DeltaCapture, label, Some(device));
        }
        Ok(Snapshot {
            stream,
            src_device: device,
            paused,
            allocations,
            shard: None,
            epoch,
            base_epoch,
            journal: Vec::new(),
        })
    }

    /// The shared checkpoint front half: pause the stream's device,
    /// quiesce, harvest the paused kernel (if any), clear the flag.
    fn pause_and_harvest(
        &self,
        stream: StreamHandle,
    ) -> Result<(usize, Option<crate::runtime::stream::PausedKernel>)> {
        let device = self.stream_device(stream)?;
        let dev = self.inner.device(device)?;
        dev.pause.store(true, Ordering::SeqCst);
        // Wait until the worker has observed the pause (quiesce processes
        // the queue up to here; a running launch returns Paused first).
        let quiesced = self.graph.quiesce(stream);
        dev.pause.store(false, Ordering::SeqCst);
        let _halted = quiesced?;
        let paused = self.graph.take_paused(stream)?;
        Ok((device, paused))
    }

    /// Dirty-tracking counters of `device` (pages tracked/dirty, current
    /// epoch) — the delta-state engine's `graph_stats`-style
    /// observability hook. Also folded into [`HetGpu::metrics`]
    /// (per-device, indexed by id).
    pub fn dirty_stats(&self, device: usize) -> Result<DirtyStats> {
        Ok(self.inner.device(device)?.mem.dirty_stats())
    }

    /// Record an epoch-cut node on `stream` (crate-internal: the
    /// coordinator places one between a shard's broadcast copies and its
    /// launch); the cell holds the new epoch once the node executes.
    pub(crate) fn record_epoch_cut(
        &self,
        stream: StreamHandle,
    ) -> Result<(EventId, Arc<OnceLock<u64>>)> {
        let out = Arc::new(OnceLock::new());
        let ev = self.graph.enqueue(stream, NodeKind::EpochCut { out: Arc::clone(&out) }, &[])?;
        Ok((ev, out))
    }

    /// Restore a snapshot onto `dst_device` and resume the stream named
    /// inside it (`snap.stream`).
    pub fn restore(&self, snap: Snapshot, dst_device: usize) -> Result<()> {
        let stream = snap.stream;
        self.restore_into(stream, snap, dst_device)
    }

    /// Restore a snapshot onto `dst_device`, resuming `stream` instead of
    /// the handle recorded in the snapshot (for snapshots shipped across
    /// contexts, where the recorded handle belongs to another context).
    /// Cross-context restores of a *paused* kernel must also rebind the
    /// captured module handle via `Snapshot::with_module` — generational
    /// handles carry no context identity, so a foreign module handle that
    /// happens to collide resolves to whatever this context loaded there
    /// (a non-colliding one fails with `HetError::InvalidHandle` when the
    /// resumed launch executes).
    pub fn restore_into(
        &self,
        stream: StreamHandle,
        snap: Snapshot,
        dst_device: usize,
    ) -> Result<()> {
        // A delta must be overlaid onto its base first: restoring its
        // sparse spans alone would leave every un-dirtied page at
        // whatever the destination holds.
        if snap.is_delta() {
            return Err(HetError::migrate(
                "cannot restore an incremental snapshot directly; apply it to its \
                 base with Snapshot::apply_delta first",
            ));
        }
        // Validate the (possibly wire-deserialized) stream handle BEFORE
        // touching any state: a stale handle must error here, not after
        // memory was overwritten and residency retagged.
        self.graph.stream_device(stream)?;
        let obs_span = self.inner.obs.begin();
        let dst = self.inner.device(dst_device)?;
        {
            let _gate = dst.exec.write().unwrap();
            for (addr, bytes) in &snap.allocations {
                dst.mem.write_bytes(*addr, bytes)?;
            }
        }
        self.inner.memory.move_residency(snap.src_device, dst_device);
        let out = self.graph.resume(stream, dst_device, snap.paused);
        if let Some(s) = obs_span {
            self.inner.obs.end(s, 0, Phase::Restore, "restore", Some(dst_device));
        }
        out
    }

    /// Live-migrate a stream to another device: checkpoint → move memory →
    /// resume. Returns the §6.3-style timing breakdown.
    pub fn migrate(&self, stream: StreamHandle, dst_device: usize) -> Result<MigrationReport> {
        let src_device = self.stream_device(stream)?;
        if src_device == dst_device {
            return Err(HetError::migrate("source and destination are the same device"));
        }
        let obs_span = self.inner.obs.begin();
        let t0 = Instant::now();
        let snap = self.checkpoint(stream)?;
        let t_ckpt = t0.elapsed();
        let bytes: u64 = snap.allocations.iter().map(|(_, b)| b.len() as u64).sum();
        let reg_bytes = snap.register_bytes();
        let t1 = Instant::now();
        self.restore(snap, dst_device)?;
        let t_restore = t1.elapsed();
        if let Some(s) = obs_span {
            self.inner.obs.end(
                s,
                0,
                Phase::Migrate,
                &format!("dev{src_device} -> dev{dst_device}"),
                Some(dst_device),
            );
        }
        // Wait for the resumed kernel to finish its current segment run.
        Ok(MigrationReport {
            src_device,
            dst_device,
            memory_bytes: bytes,
            register_bytes: reg_bytes,
            checkpoint_us: t_ckpt.as_secs_f64() * 1e6,
            restore_us: t_restore.as_secs_f64() * 1e6,
            modeled_downtime_ms: MigrationReport::model_downtime_ms(
                bytes + reg_bytes,
                self.inner.device(src_device)?.kind,
                self.inner.device(dst_device)?.kind,
            ),
        })
    }
}

impl Drop for HetGpu {
    fn drop(&mut self) {
        self.graph.shutdown();
        for h in self.executors.drain(..) {
            let _ = h.join();
        }
        // Wake the background tier-2 compiler out of its queue wait and
        // join it (any in-progress compile finishes first — installing
        // into a cache nobody will read again is harmless).
        self.inner.jit.shutdown_compiler();
        if let Some(h) = self.jit_compiler.take() {
            let _ = h.join();
        }
        // Dump-on-drop: `HETGPU_TRACE=<path>` armed tracing at creation
        // and recorded the destination; export after every executor has
        // joined so the recorder is complete and quiescent.
        if let Some(path) = self.inner.obs.dump_path() {
            if let Err(e) = self.inner.obs.export_trace(&path, &self.device_track_names()) {
                eprintln!("hetgpu: HETGPU_TRACE export to {} failed: {e}", path.display());
            }
        }
    }
}

/// Builder describing one kernel launch (API v2): dimensions, typed
/// arguments, an optional Tensix execution-mode hint (paper §4.4 user
/// hints), and an optional **working-set hint** consumed by sharded
/// launches to broadcast/merge only the named allocations instead of
/// every live byte of unified memory.
///
/// Created by [`HetGpu::launch`]; consumed by [`LaunchBuilder::record`]
/// (stream launch) or [`LaunchBuilder::sharded`] (coordinator grid
/// split).
#[must_use = "a launch builder does nothing until `record` or `sharded` is called"]
pub struct LaunchBuilder<'a> {
    ctx: &'a HetGpu,
    module: ModuleHandle,
    kernel: String,
    dims: Option<LaunchDims>,
    args: Vec<Arg>,
    tensix_mode: Option<TensixMode>,
    working_set: Option<Vec<GpuPtr>>,
    atomics: AtomicsMode,
    fault_policy: FaultPolicy,
    analysis: Option<AnalysisLevel>,
}

impl<'a> LaunchBuilder<'a> {
    /// Grid/block dimensions (required).
    pub fn dims(mut self, dims: LaunchDims) -> Self {
        self.dims = Some(dims);
        self
    }

    /// Append one typed argument (`&Buffer<T>`, `GpuPtr`, `u32`, `i32`,
    /// `u64`, `i64`, `f32`, `bool`, or a prebuilt [`Arg`]).
    pub fn arg(mut self, a: impl Into<Arg>) -> Self {
        self.args.push(a.into());
        self
    }

    /// Append a slice of prebuilt arguments.
    pub fn args(mut self, args: &[Arg]) -> Self {
        self.args.extend_from_slice(args);
        self
    }

    /// Override the Tensix execution-mode heuristic (paper §4.4).
    pub fn tensix_mode(mut self, mode: TensixMode) -> Self {
        self.tensix_mode = Some(mode);
        self
    }

    /// Name the allocations this launch reads or writes (by any pointer
    /// into them) — an **override** restricting the regions a sharded
    /// launch considers at all. Since the delta-state engine, the hint
    /// is no longer required for sub-O(total-memory) sharding: unhinted
    /// launches consider every live allocation but baseline, broadcast
    /// (steady-state), and merge only **dirty pages**. The hint still
    /// shrinks the first-contact broadcast and the page-scan universe.
    /// Launches on a single stream ignore it.
    pub fn working_set(mut self, ptrs: &[GpuPtr]) -> Self {
        self.working_set = Some(ptrs.to_vec());
        self
    }

    /// How a **sharded** launch composes global atomics across shards
    /// (see [`AtomicsMode`]): `Auto` (default) journals commutative
    /// atomics whenever the grid spans devices and the kernel performs
    /// global atomics, `Journal` forces the protocol, `Unsynchronized`
    /// restores the pre-protocol last-writer-wins merge. Single-stream
    /// launches ignore it.
    pub fn atomics_mode(mut self, mode: AtomicsMode) -> Self {
        self.atomics = mode;
        self
    }

    /// How a **sharded** launch responds to a shard's device fault (see
    /// [`FaultPolicy`]): `FailFast` (default) quarantines and surfaces a
    /// typed `DeviceLost`; `Retry { max }` re-executes the failed shard
    /// on the same device with capped backoff; `Redistribute`
    /// re-executes its block range on the surviving devices — either
    /// recovery joins bit-identical to the fault-free run. Single-stream
    /// launches ignore it.
    pub fn fault_policy(mut self, policy: FaultPolicy) -> Self {
        self.fault_policy = policy;
        self
    }

    /// How much the static analyzer gates **this** launch (see
    /// [`AnalysisLevel`]): `Strict` refuses kernels carrying any
    /// load-time `Warning`-or-above diagnostic, `Warn` (the context
    /// default unless `HETGPU_ANALYZE` says otherwise) still refuses a
    /// *provably* out-of-bounds access at the requested dims/args, `Off`
    /// skips pre-flight entirely. The builder setting wins over the
    /// environment default.
    pub fn analysis(mut self, level: AnalysisLevel) -> Self {
        self.analysis = Some(level);
        self
    }

    #[allow(clippy::type_complexity)]
    fn build_spec(
        self,
    ) -> Result<(
        &'a HetGpu,
        LaunchSpec,
        Option<Vec<GpuPtr>>,
        AtomicsMode,
        FaultPolicy,
        AnalysisLevel,
    )> {
        let dims = self
            .dims
            .ok_or_else(|| HetError::runtime("launch dims not set (LaunchBuilder::dims)"))?;
        let level = self.analysis.unwrap_or(self.ctx.analysis_default);
        let spec = LaunchSpec {
            module: self.module,
            kernel: self.kernel,
            dims,
            args: self.args,
            tensix_mode_hint: self.tensix_mode,
        };
        Ok((self.ctx, spec, self.working_set, self.atomics, self.fault_policy, level))
    }

    /// Record the launch on `stream`; returns the launch's event
    /// (queryable via [`HetGpu::event_query`], waitable from other
    /// streams via [`HetGpu::wait_event`]). Pre-flights the launch
    /// against the cached analysis report first: a statically-rejected
    /// launch fails here, before anything enters the event graph.
    pub fn record(self, stream: StreamHandle) -> Result<EventId> {
        let (ctx, spec, _ws, _atomics, _policy, level) = self.build_spec()?;
        // The launch's root span covers the record phase; the executor
        // later parents graph-schedule/dispatch (and any resume spans)
        // under the same trace id.
        let obs = &ctx.inner.obs;
        let root = obs.begin();
        let trace = root.map_or(0, |s| s.id);
        let label = root.map(|_| spec.kernel.clone());
        let a_span = obs.begin();
        let pf = ctx.preflight(&spec, level);
        if let Some(s) = a_span {
            obs.end(s, trace, Phase::Analyze, &spec.kernel, None);
        }
        let out = pf.and_then(|_| ctx.record_launch(stream, spec, None, &[], None, trace));
        if let Some(s) = root {
            obs.end(s, 0, Phase::Record, label.as_deref().unwrap_or(""), None);
        }
        out
    }

    /// Split the launch's grid over `devices` through the coordinator
    /// (shards start executing immediately); join with
    /// [`ShardedLaunch::wait`]. Consumes the working-set hint, the
    /// atomics mode, the fault policy, and the analysis level (the
    /// coordinator additionally rejects ordered-atomic kernels up front —
    /// their cross-shard journal replay cannot compose).
    pub fn sharded(self, devices: &[usize]) -> Result<ShardedLaunch<'a>> {
        let (ctx, spec, ws, atomics, policy, level) = self.build_spec()?;
        // Root span of the whole sharded launch: handed to the
        // coordinator, which ends it at the join (`ShardedLaunch::wait`)
        // so it covers record → shard dispatch → merge/replay.
        let obs = &ctx.inner.obs;
        let root = obs.begin();
        let trace = root.map_or(0, |s| s.id);
        let a_span = obs.begin();
        let pf = ctx.preflight(&spec, level);
        if let Some(s) = a_span {
            obs.end(s, trace, Phase::Analyze, &spec.kernel, None);
        }
        let out = pf.and_then(|_| {
            Coordinator::new(ctx)
                .launch_sharded(spec, ws.as_deref(), devices, atomics, policy, level, root)
        });
        if out.is_err() {
            // A launch that never started still closes its root span so
            // the flight recorder shows the failed record attempt.
            if let Some(s) = root {
                obs.end(s, 0, Phase::Record, "sharded launch (failed to record)", None);
            }
        }
        out
    }
}
