//! The public hetGPU API — the CUDA-like abstraction layer of paper §4.3.
//!
//! `HetGpu` is the context a program links against (`libhetgpu.so` in the
//! paper): device discovery, module loading (from CUDA source or hetIR
//! text), unified memory (`malloc`/`memcpy`), stream creation, kernel
//! launch, and the checkpoint/migration entry points.

use crate::coordinator::shard::ShardRange;
use crate::coordinator::Coordinator;
use crate::error::{HetError, Result};
use crate::frontend;
use crate::hetir::{self, module::Module};
use crate::migrate::state::{MigrationReport, Snapshot};
use crate::runtime::device::{Device, DeviceKind};
use crate::runtime::events::{EventGraph, EventId, EventStatus, NodeKind};
use crate::runtime::jit::JitCache;
use crate::runtime::launch::{Arg, LaunchSpec};
use crate::runtime::memory::{GpuPtr, MemoryManager};
use crate::runtime::stream::{Stream, StreamStats};
use crate::runtime::RuntimeInner;
use crate::sim::simt::LaunchDims;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Handle to a loaded hetIR module.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModuleHandle(pub usize);

/// Handle to a stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamHandle(pub usize);

/// The hetGPU context.
pub struct HetGpu {
    inner: Arc<RuntimeInner>,
    /// The command DAG every stream records into.
    graph: Arc<EventGraph>,
    /// Executor pool draining the graph (joined on drop).
    executors: Vec<JoinHandle<()>>,
    streams: Mutex<Vec<Stream>>,
}

impl HetGpu {
    /// Create a context with the given simulated devices. Each device's
    /// block-dispatch worker count comes from `HETGPU_SIM_THREADS`
    /// (default: host cores).
    pub fn with_devices(kinds: &[DeviceKind]) -> Result<HetGpu> {
        HetGpu::build(kinds, None)
    }

    /// Create a context with an explicit per-device dispatch worker count
    /// (overrides `HETGPU_SIM_THREADS`; `1` forces sequential block
    /// execution).
    pub fn with_devices_and_workers(kinds: &[DeviceKind], workers: usize) -> Result<HetGpu> {
        HetGpu::build(kinds, Some(workers))
    }

    fn build(kinds: &[DeviceKind], workers: Option<usize>) -> Result<HetGpu> {
        if kinds.is_empty() {
            return Err(HetError::runtime("no devices"));
        }
        let devices: Vec<Device> = kinds
            .iter()
            .enumerate()
            .map(|(i, k)| match workers {
                Some(w) => Device::new_with_workers(i, *k, w),
                None => Device::new(i, *k),
            })
            .collect();
        let inner = Arc::new(RuntimeInner {
            devices,
            modules: std::sync::RwLock::new(Vec::new()),
            jit: JitCache::new(),
            memory: MemoryManager::new(crate::runtime::device::DEVICE_MEM_BYTES),
        });
        let graph = EventGraph::new(inner.clone());
        // Enough executors that every device can be mid-launch while a few
        // extra streams overlap copies; executors block while a node runs.
        let executors = EventGraph::spawn_executors(&graph, (kinds.len() * 2).clamp(2, 8));
        Ok(HetGpu { inner, graph, executors, streams: Mutex::new(Vec::new()) })
    }

    /// Create a context with all four paper devices.
    pub fn full_testbed() -> Result<HetGpu> {
        HetGpu::with_devices(&DeviceKind::all())
    }

    /// Dispatch worker threads device `id` spreads thread blocks over.
    pub fn sim_workers(&self, id: usize) -> Result<usize> {
        Ok(self.inner.device(id)?.engine.workers())
    }

    pub fn device_count(&self) -> usize {
        self.inner.devices.len()
    }

    pub fn device_kind(&self, id: usize) -> Result<DeviceKind> {
        Ok(self.inner.device(id)?.kind)
    }

    /// Shared runtime internals (benches/tests poke at the JIT cache).
    pub fn runtime(&self) -> &RuntimeInner {
        &self.inner
    }

    /// The command DAG (crate-internal: coordinator + tests).
    pub(crate) fn graph(&self) -> &Arc<EventGraph> {
        &self.graph
    }

    /// Multi-device coordinator view of this context (paper §4.3/§6.3
    /// L3 coordination): shard one grid over several devices, rebalance
    /// paused shards.
    pub fn coordinator(&self) -> Coordinator<'_> {
        Coordinator::new(self)
    }

    // ---- modules ----

    /// Compile CUDA-subset source into a loaded module.
    pub fn compile_cuda(&self, src: &str) -> Result<ModuleHandle> {
        let module = frontend::compile(src, "cuda-module")?;
        self.load_module(module)
    }

    /// Load a hetIR module from its text-assembly form ("the binary").
    pub fn load_module_text(&self, text: &str) -> Result<ModuleHandle> {
        let module = hetir::parser::parse_module(text)?;
        self.load_module(module)
    }

    /// Load an in-memory hetIR module (verifies every kernel first).
    pub fn load_module(&self, module: Module) -> Result<ModuleHandle> {
        hetir::verify::verify_module(&module)?;
        let mut mods = self.inner.modules.write().unwrap();
        mods.push(module);
        Ok(ModuleHandle(mods.len() - 1))
    }

    // ---- memory ----

    /// Allocate device memory resident on `device`.
    pub fn malloc_on(&self, bytes: u64, device: usize) -> Result<GpuPtr> {
        self.inner.device(device)?;
        self.inner.memory.alloc(bytes, device)
    }

    pub fn free(&self, ptr: GpuPtr) -> Result<()> {
        self.inner.memory.free(ptr)
    }

    /// Host→device copy (to wherever the buffer is resident). Synchronous
    /// and kernel-ordered: takes the device gate exclusively, so it waits
    /// for in-flight launches on the device rather than racing them (the
    /// pre-event-graph blocking behavior); use
    /// [`HetGpu::memcpy_h2d_async`] for a stream-ordered copy that
    /// overlaps other streams' kernels.
    pub fn memcpy_h2d(&self, dst: GpuPtr, data: &[u8]) -> Result<()> {
        let (base, size, device) = self.inner.memory.lookup(dst)?;
        if dst.0 + data.len() as u64 > base + size {
            return Err(HetError::runtime("h2d copy out of bounds"));
        }
        let dev = self.inner.device(device)?;
        let _gate = dev.exec.write().unwrap();
        dev.mem.write_bytes(dst.0, data)
    }

    /// Device→host copy. Synchronous and kernel-ordered (see
    /// [`HetGpu::memcpy_h2d`]): waits for in-flight launches on the
    /// device, so it never reads a half-written image.
    pub fn memcpy_d2h(&self, out: &mut [u8], src: GpuPtr) -> Result<()> {
        let (base, size, device) = self.inner.memory.lookup(src)?;
        if src.0 + out.len() as u64 > base + size {
            return Err(HetError::runtime("d2h copy out of bounds"));
        }
        let dev = self.inner.device(device)?;
        let _gate = dev.exec.write().unwrap();
        dev.mem.read_bytes_into(src.0, out)
    }

    /// Typed convenience: upload an `f32` slice.
    pub fn upload_f32(&self, dst: GpuPtr, data: &[f32]) -> Result<()> {
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        self.memcpy_h2d(dst, &bytes)
    }

    /// Typed convenience: download an `f32` slice.
    pub fn download_f32(&self, src: GpuPtr, n: usize) -> Result<Vec<f32>> {
        let mut bytes = vec![0u8; n * 4];
        self.memcpy_d2h(&mut bytes, src)?;
        Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    /// Typed convenience: upload a `u32` slice.
    pub fn upload_u32(&self, dst: GpuPtr, data: &[u32]) -> Result<()> {
        let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
        self.memcpy_h2d(dst, &bytes)
    }

    /// Typed convenience: download a `u32` slice.
    pub fn download_u32(&self, src: GpuPtr, n: usize) -> Result<Vec<u32>> {
        let mut bytes = vec![0u8; n * 4];
        self.memcpy_d2h(&mut bytes, src)?;
        Ok(bytes.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    // ---- streams & launch ----

    /// Create a stream bound to `device`. Streams are thin graph handles —
    /// creating one spawns no thread.
    pub fn create_stream(&self, device: usize) -> Result<StreamHandle> {
        self.inner.device(device)?;
        let mut streams = self.streams.lock().unwrap();
        let id = self.graph.add_stream(device);
        debug_assert_eq!(id, streams.len());
        streams.push(Stream::new(id, self.graph.clone()));
        Ok(StreamHandle(id))
    }

    /// Which device a stream currently runs on.
    pub fn stream_device(&self, s: StreamHandle) -> Result<usize> {
        self.graph.stream_device(s.0)
    }

    pub(crate) fn with_stream<T>(
        &self,
        s: StreamHandle,
        f: impl FnOnce(&Stream) -> Result<T>,
    ) -> Result<T> {
        // Clone the thin handle out so the registry lock is not held
        // across blocking stream operations (synchronize/quiesce).
        let st = {
            let streams = self.streams.lock().unwrap();
            streams.get(s.0).ok_or_else(|| HetError::runtime("bad stream handle"))?.clone()
        };
        f(&st)
    }

    /// Asynchronously launch a kernel on a stream; returns the launch's
    /// event (queryable via [`HetGpu::event_query`], waitable from other
    /// streams via [`HetGpu::wait_event`]).
    pub fn launch(
        &self,
        stream: StreamHandle,
        module: ModuleHandle,
        kernel: &str,
        dims: LaunchDims,
        args: &[Arg],
    ) -> Result<EventId> {
        let spec = LaunchSpec {
            module: module.0,
            kernel: kernel.to_string(),
            dims,
            args: args.to_vec(),
            tensix_mode_hint: None,
        };
        self.with_stream(stream, |s| s.launch(spec))
    }

    /// Launch with a Tensix execution-mode hint (paper §4.4 user hints).
    pub fn launch_with_mode(
        &self,
        stream: StreamHandle,
        module: ModuleHandle,
        kernel: &str,
        dims: LaunchDims,
        args: &[Arg],
        mode: crate::isa::tensix_isa::TensixMode,
    ) -> Result<EventId> {
        let spec = LaunchSpec {
            module: module.0,
            kernel: kernel.to_string(),
            dims,
            args: args.to_vec(),
            tensix_mode_hint: Some(mode),
        };
        self.with_stream(stream, |s| s.launch(spec))
    }

    /// Launch only the blocks in `range` of a logically larger grid (the
    /// coordinator's sharded-execution primitive).
    pub(crate) fn launch_shard(
        &self,
        stream: StreamHandle,
        module: ModuleHandle,
        kernel: &str,
        dims: LaunchDims,
        args: &[Arg],
        range: ShardRange,
    ) -> Result<EventId> {
        let spec = LaunchSpec {
            module: module.0,
            kernel: kernel.to_string(),
            dims,
            args: args.to_vec(),
            tensix_mode_hint: None,
        };
        self.with_stream(stream, |s| {
            s.enqueue(NodeKind::Launch { spec, shard: Some(range) }, &[])
        })
    }

    /// Asynchronous host→device copy, ordered with the stream's other
    /// commands (the event-graph analog of `cudaMemcpyAsync`).
    pub fn memcpy_h2d_async(
        &self,
        stream: StreamHandle,
        dst: GpuPtr,
        data: &[u8],
    ) -> Result<EventId> {
        // Fail unknown pointers and overruns at record time, like the
        // synchronous path (the executor re-checks at execution, when the
        // allocation table may have changed).
        let (base, size, _device) = self.inner.memory.lookup(dst)?;
        if dst.0 + data.len() as u64 > base + size {
            return Err(HetError::runtime("h2d copy out of bounds"));
        }
        self.with_stream(stream, |s| {
            s.enqueue(NodeKind::CopyH2D { dst, data: data.to_vec() }, &[])
        })
    }

    /// Make `stream` wait for `event` (recorded on any stream) before
    /// running its subsequent commands — a cross-stream DAG edge.
    pub fn wait_event(&self, stream: StreamHandle, event: EventId) -> Result<EventId> {
        self.graph.query(event)?; // must name a recorded event
        self.with_stream(stream, |s| s.enqueue(NodeKind::Marker, &[event]))
    }

    /// Status of a recorded event.
    pub fn event_query(&self, event: EventId) -> Result<EventStatus> {
        self.graph.query(event)
    }

    /// Wait for all work on a stream (propagates sticky errors).
    pub fn synchronize(&self, stream: StreamHandle) -> Result<()> {
        self.with_stream(stream, |s| s.synchronize())
    }

    /// Per-stream stats (launches, model cycles, wall time), including the
    /// per-device breakdown for streams that executed on several devices.
    pub fn stream_stats(&self, stream: StreamHandle) -> Result<StreamStats> {
        self.with_stream(stream, |s| s.stats())
    }

    // ---- checkpoint / migration (paper §4.2, §6.3) ----

    /// Cooperatively checkpoint a stream: sets the device pause flag,
    /// waits for the in-flight kernel to dump at its next barrier (or
    /// finish), and returns the device-neutral snapshot (kernel state +
    /// all global allocations on the device).
    pub fn checkpoint(&self, stream: StreamHandle) -> Result<Snapshot> {
        let device = self.stream_device(stream)?;
        let dev = self.inner.device(device)?;
        dev.pause.store(true, Ordering::SeqCst);
        // Wait until the worker has observed the pause (quiesce processes
        // the queue up to here; a running launch returns Paused first).
        let _halted = self.with_stream(stream, |s| s.quiesce())?;
        dev.pause.store(false, Ordering::SeqCst);
        let paused = self.with_stream(stream, |s| s.take_paused())?;
        // Collect global memory: every allocation resident on the device.
        // The exclusive gate keeps concurrent launches of *other* streams
        // on this device out of the capture window.
        let allocs = self.inner.memory.allocations_on(device);
        let mut mem_blobs = Vec::with_capacity(allocs.len());
        {
            let _gate = dev.exec.write().unwrap();
            for (addr, size) in allocs {
                let mut bytes = vec![0u8; size as usize];
                dev.mem.read_bytes_into(addr, &mut bytes)?;
                mem_blobs.push((addr, bytes));
            }
        }
        // Launches of *other* streams overlapping on this device may also
        // have observed the pause flag and halted; resume them in place so
        // a checkpoint of one stream never silently strands its neighbors.
        self.graph.resume_collateral(device, stream.0);
        Ok(Snapshot { src_device: device, paused, allocations: mem_blobs, shard: None })
    }

    /// Restore a snapshot onto `dst_device` and resume the stream there.
    pub fn restore(&self, stream: StreamHandle, snap: Snapshot, dst_device: usize) -> Result<()> {
        let dst = self.inner.device(dst_device)?;
        {
            let _gate = dst.exec.write().unwrap();
            for (addr, bytes) in &snap.allocations {
                dst.mem.write_bytes(*addr, bytes)?;
            }
        }
        self.inner.memory.move_residency(snap.src_device, dst_device);
        self.with_stream(stream, |s| s.resume(dst_device, snap.paused))
    }

    /// Live-migrate a stream to another device: checkpoint → move memory →
    /// resume. Returns the §6.3-style timing breakdown.
    pub fn migrate(&self, stream: StreamHandle, dst_device: usize) -> Result<MigrationReport> {
        let src_device = self.stream_device(stream)?;
        if src_device == dst_device {
            return Err(HetError::migrate("source and destination are the same device"));
        }
        let t0 = Instant::now();
        let snap = self.checkpoint(stream)?;
        let t_ckpt = t0.elapsed();
        let bytes: u64 = snap.allocations.iter().map(|(_, b)| b.len() as u64).sum();
        let reg_bytes = snap.register_bytes();
        let t1 = Instant::now();
        self.restore(stream, snap, dst_device)?;
        let t_restore = t1.elapsed();
        // Wait for the resumed kernel to finish its current segment run.
        Ok(MigrationReport {
            src_device,
            dst_device,
            memory_bytes: bytes,
            register_bytes: reg_bytes,
            checkpoint_us: t_ckpt.as_secs_f64() * 1e6,
            restore_us: t_restore.as_secs_f64() * 1e6,
            modeled_downtime_ms: MigrationReport::model_downtime_ms(
                bytes + reg_bytes,
                self.inner.device(src_device)?.kind,
                self.inner.device(dst_device)?.kind,
            ),
        })
    }
}

impl Drop for HetGpu {
    fn drop(&mut self) {
        self.graph.shutdown();
        for h in self.executors.drain(..) {
            let _ = h.join();
        }
    }
}
