//! Kernel launch specification: arguments, dimension checks, and the
//! Tensix execution-mode heuristic (paper §4.4 "the runtime decides which
//! strategy per kernel ... based on heuristics. The user can also give
//! hints").

use crate::error::{HetError, Result};
use crate::hetir::instr::Inst;
use crate::hetir::module::Kernel;
use crate::hetir::passes::{scalarize, uniformity};
use crate::hetir::types::{AddrSpace, Type, Value};
use crate::isa::tensix_isa::TensixMode;
use crate::isa::AtomicsClass;
use crate::runtime::memory::{Buffer, GpuPtr, Pod};
use crate::runtime::ModuleHandle;
use crate::sim::simt::LaunchDims;

/// A kernel argument, CUDA-style.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arg {
    Ptr(GpuPtr),
    U32(u32),
    I32(i32),
    U64(u64),
    I64(i64),
    F32(f32),
    Pred(bool),
}

impl Arg {
    /// Convert to a hetIR value, checking against the parameter type.
    pub fn to_value(&self, want: Type, pname: &str) -> Result<Value> {
        let v = match (self, want) {
            (Arg::Ptr(p), Type::Ptr(AddrSpace::Global)) => Value::ptr(p.0, AddrSpace::Global),
            (Arg::U32(v), Type::Scalar(crate::hetir::types::Scalar::U32)) => Value::u32(*v),
            (Arg::I32(v), Type::Scalar(crate::hetir::types::Scalar::I32)) => Value::i32(*v),
            (Arg::U64(v), Type::Scalar(crate::hetir::types::Scalar::U64)) => Value::u64(*v),
            (Arg::I64(v), Type::Scalar(crate::hetir::types::Scalar::I64)) => Value::i64(*v),
            (Arg::F32(v), Type::Scalar(crate::hetir::types::Scalar::F32)) => Value::f32(*v),
            (Arg::Pred(v), Type::Scalar(crate::hetir::types::Scalar::Pred)) => Value::pred(*v),
            (got, want) => {
                return Err(HetError::runtime(format!(
                    "argument type mismatch for `{pname}`: kernel wants {want}, got {got:?}"
                )))
            }
        };
        Ok(v)
    }
}

/// Typed-argument conversions for the `LaunchBuilder`'s `arg` method:
/// plain Rust values, raw pointers, and typed buffers all coerce into the
/// CUDA-style argument enum.
impl From<GpuPtr> for Arg {
    fn from(p: GpuPtr) -> Arg {
        Arg::Ptr(p)
    }
}
impl<T: Pod> From<&Buffer<T>> for Arg {
    fn from(b: &Buffer<T>) -> Arg {
        Arg::Ptr(b.ptr())
    }
}
impl From<u32> for Arg {
    fn from(v: u32) -> Arg {
        Arg::U32(v)
    }
}
impl From<i32> for Arg {
    fn from(v: i32) -> Arg {
        Arg::I32(v)
    }
}
impl From<u64> for Arg {
    fn from(v: u64) -> Arg {
        Arg::U64(v)
    }
}
impl From<i64> for Arg {
    fn from(v: i64) -> Arg {
        Arg::I64(v)
    }
}
impl From<f32> for Arg {
    fn from(v: f32) -> Arg {
        Arg::F32(v)
    }
}
impl From<bool> for Arg {
    fn from(v: bool) -> Arg {
        Arg::Pred(v)
    }
}

/// How a **sharded** launch composes global-memory atomics across shards
/// (`LaunchBuilder::atomics_mode`; single-stream launches ignore it).
///
/// Sharded grids execute against per-device memory images, so in-place
/// read-modify-write between shards does not compose by itself. Under the
/// journal protocol every commutative global atomic applies to the
/// shard's image *and* appends a typed entry to the shard's
/// [`crate::delta::journal::AtomicJournal`]; the join replays all shards'
/// entries against the launch baseline in deterministic order (shard id,
/// then program order) in place of the last-writer-wins byte merge for
/// the journaled words. Ordered ops (Exch/Cas) do not commute and fail
/// closed with [`crate::error::HetError::OrderedAtomic`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AtomicsMode {
    /// Journal when the grid spans more than one device **and** the
    /// kernel performs global atomics ([`KernelFeatures::global_atomics`]);
    /// otherwise run plain. The default.
    #[default]
    Auto,
    /// Always journal (even when the kernel looks atomics-free).
    Journal,
    /// Pre-protocol behavior: shards apply atomics to their private
    /// images only and the join byte-merges last-writer-wins — cross-shard
    /// RMW traffic silently does not compose. Kept for atomics-free
    /// kernels that want zero protocol overhead and for A/B measurement.
    Unsynchronized,
}

/// A fully-specified launch request.
#[derive(Debug, Clone)]
pub struct LaunchSpec {
    /// Generational handle of the loaded module (revalidated at
    /// execution time, so launches queued across an `unload_module` fail
    /// with a typed stale-handle error).
    pub module: ModuleHandle,
    pub kernel: String,
    pub dims: LaunchDims,
    pub args: Vec<Arg>,
    /// Optional user hint overriding the Tensix mode heuristic.
    pub tensix_mode_hint: Option<TensixMode>,
}

/// Validate launch geometry with checked arithmetic *before* anything
/// touches the unchecked `grid_size`/`block_size` accessors on the hot
/// path: 3-D products that overflow `u32` (a debug-build panic and a
/// silently wrapped grid in release builds) become a clear runtime error.
/// Delegates to [`LaunchDims::validate`], the single home of the geometry
/// rules shared with both simulators; per-architecture block-size caps are
/// enforced by the target engine (SIMT's 1024-thread limit does not apply
/// to Tensix MIMD/multi-core launches).
pub fn validate_dims(dims: LaunchDims) -> Result<(u32, u32)> {
    dims.validate()
}

/// Convert launch args to typed values against the kernel signature.
pub fn args_to_values(kernel: &Kernel, args: &[Arg]) -> Result<Vec<Value>> {
    if args.len() != kernel.params.len() {
        return Err(HetError::runtime(format!(
            "kernel `{}` takes {} args, got {}",
            kernel.name,
            kernel.params.len(),
            args.len()
        )));
    }
    args.iter()
        .zip(&kernel.params)
        .map(|(a, p)| a.to_value(p.ty, &p.name))
        .collect()
}

/// Static kernel features consulted by the mode heuristic.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct KernelFeatures {
    pub has_barrier: bool,
    pub has_shared: bool,
    pub has_team_ops: bool,
    pub has_divergence: bool,
    /// hetIR-level classification of the kernel's global-memory atomics —
    /// the same classification the lowered backend programs expose via
    /// `atomics_class()`. The coordinator's `AtomicsMode::Auto` keys on
    /// it: `None` skips journaling entirely.
    pub global_atomics: AtomicsClass,
}

pub fn kernel_features(k: &Kernel) -> KernelFeatures {
    let mut f = KernelFeatures::default();
    k.visit_insts(|i| match i {
        Inst::Bar { .. } => f.has_barrier = true,
        Inst::Ld { space: AddrSpace::Shared, .. }
        | Inst::St { space: AddrSpace::Shared, .. }
        | Inst::Atom { space: AddrSpace::Shared, .. } => f.has_shared = true,
        Inst::Atom { op, space: AddrSpace::Global, .. } => {
            f.global_atomics = f.global_atomics.with(*op)
        }
        Inst::Vote { .. } | Inst::Ballot { .. } | Inst::Shfl { .. } => f.has_team_ops = true,
        _ => {}
    });
    if k.shared_bytes > 0 {
        f.has_shared = true;
    }
    // Divergence: any If/While controlled by a varying predicate.
    let uni = uniformity::run(k);
    fn walk(stmts: &[crate::hetir::module::Stmt], uni: &uniformity::Uniformity) -> bool {
        use crate::hetir::module::Stmt;
        for s in stmts {
            match s {
                Stmt::If { cond, then_b, else_b } => {
                    if uni.is_varying(*cond) || walk(then_b, uni) || walk(else_b, uni) {
                        return true;
                    }
                }
                Stmt::While { cond, cond_reg, body } => {
                    if uni.is_varying(*cond_reg) || walk(cond, uni) || walk(body, uni) {
                        return true;
                    }
                }
                _ => {}
            }
        }
        false
    }
    f.has_divergence = walk(&k.body, &uni);
    f
}

/// The paper's §4.4 heuristic: kernels that need cross-thread coordination
/// run vectorized (single core when the block fits, multi-core otherwise);
/// "for highly divergent workloads, forcing SIMT behavior is detrimental,
/// so our runtime can instead run each thread independently (pure MIMD)".
pub fn choose_tensix_mode(k: &Kernel, dims: LaunchDims) -> TensixMode {
    let f = kernel_features(k);
    let needs_vector = f.has_barrier || f.has_shared || f.has_team_ops;
    if !needs_vector && f.has_divergence {
        return TensixMode::ScalarMimd;
    }
    // A kernel that is almost entirely warp-uniform work gains nothing
    // from lockstep vector execution — every lane computes the same
    // values — while MIMD lets the scalarization pass hoist that work
    // into straight scalar code per thread.
    if !needs_vector && scalarize::profile(k).mostly_uniform(90) {
        return TensixMode::ScalarMimd;
    }
    if dims.block_size() <= 32 {
        TensixMode::VectorSingleCore
    } else {
        TensixMode::VectorMultiCore
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::compile;

    #[test]
    fn arg_type_checking() {
        let m = compile(
            "__global__ void k(float* p, unsigned n, float a) { p[n] = a; }",
            "m",
        )
        .unwrap();
        let k = m.kernel("k").unwrap();
        let good = [Arg::Ptr(GpuPtr(4096)), Arg::U32(1), Arg::F32(2.0)];
        assert!(args_to_values(k, &good).is_ok());
        let wrong_ty = [Arg::Ptr(GpuPtr(4096)), Arg::F32(1.0), Arg::F32(2.0)];
        assert!(args_to_values(k, &wrong_ty).is_err());
        let wrong_n = [Arg::Ptr(GpuPtr(4096))];
        assert!(args_to_values(k, &wrong_n).is_err());
    }

    #[test]
    fn dims_validation_catches_overflow_and_empties() {
        assert!(validate_dims(LaunchDims::d1(4, 256)).is_ok());
        // 3-D products that wrap u32 must error, not panic.
        let huge = LaunchDims { grid: [u32::MAX, u32::MAX, u32::MAX], block: [1, 1, 1] };
        let e = validate_dims(huge).unwrap_err();
        assert!(e.to_string().contains("overflow"), "{e}");
        let wide_block = LaunchDims { grid: [1, 1, 1], block: [65536, 65536, 1] };
        assert!(validate_dims(wide_block).is_err());
        assert!(validate_dims(LaunchDims::d1(0, 32)).is_err());
        assert!(validate_dims(LaunchDims::d1(1, 0)).is_err());
        // Block-size caps are per-architecture (SIMT rejects >1024 in its
        // engine; Tensix MIMD legitimately accepts larger blocks).
        assert!(validate_dims(LaunchDims::d1(1, 2048)).is_ok());
    }

    #[test]
    fn mode_heuristic_matches_paper() {
        // Divergent, barrier-free kernel (Monte-Carlo-like) → MIMD.
        let mc = compile(
            r#"__global__ void mc(unsigned* hits, unsigned n) {
                unsigned i = blockIdx.x * blockDim.x + threadIdx.x;
                unsigned s = i + 1u;
                unsigned local = 0u;
                for (unsigned j = 0u; j < n; j++) {
                    unsigned x = hetgpu_rand(s);
                    if (x % 2u == 0u) local += 1u;
                }
                atomicAdd(&hits[0], local);
            }"#,
            "m",
        )
        .unwrap();
        assert_eq!(
            choose_tensix_mode(mc.kernel("mc").unwrap(), LaunchDims::d1(4, 64)),
            TensixMode::ScalarMimd
        );

        // Shared-memory kernel → vector; small block → single core.
        let sh = compile(
            r#"__global__ void s(float* p) {
                __shared__ float t[32];
                t[threadIdx.x] = p[threadIdx.x];
                __syncthreads();
                p[threadIdx.x] = t[31u - threadIdx.x];
            }"#,
            "m",
        )
        .unwrap();
        let k = sh.kernel("s").unwrap();
        assert_eq!(choose_tensix_mode(k, LaunchDims::d1(1, 32)), TensixMode::VectorSingleCore);
        assert_eq!(choose_tensix_mode(k, LaunchDims::d1(1, 128)), TensixMode::VectorMultiCore);
    }

    #[test]
    fn mostly_uniform_kernels_prefer_mimd() {
        // Nearly all the work is warp-uniform (every lane would compute the
        // same values in lockstep) → MIMD, even with no divergence at all.
        let u = compile(
            r#"__global__ void u(unsigned* p, unsigned n) {
                unsigned a = n * 3u;
                unsigned b = a ^ 17u;
                unsigned c = b + n;
                p[0] = a + b + c;
            }"#,
            "m",
        )
        .unwrap();
        assert_eq!(
            choose_tensix_mode(u.kernel("u").unwrap(), LaunchDims::d1(4, 32)),
            TensixMode::ScalarMimd
        );

        // Per-thread addressing keeps the profile varying → vector modes
        // still win for regular data-parallel kernels.
        let v = compile(
            "__global__ void v(unsigned* p) { p[threadIdx.x] = threadIdx.x * 2u; }",
            "m",
        )
        .unwrap();
        assert_eq!(
            choose_tensix_mode(v.kernel("v").unwrap(), LaunchDims::d1(4, 32)),
            TensixMode::VectorSingleCore
        );
    }

    #[test]
    fn features_classify_global_atomics() {
        let m = compile(
            "__global__ void k(unsigned* p) { atomicAdd(&p[0], 1u); atomicXor(&p[1], 3u); }",
            "m",
        )
        .unwrap();
        assert_eq!(
            kernel_features(m.kernel("k").unwrap()).global_atomics,
            AtomicsClass::Commutative
        );
        let ordered = compile(
            "__global__ void k(unsigned* p) { atomicExch(&p[0], 1u); }",
            "m",
        )
        .unwrap();
        assert_eq!(
            kernel_features(ordered.kernel("k").unwrap()).global_atomics,
            AtomicsClass::Ordered
        );
        let none = compile("__global__ void k(unsigned* p) { p[0] = 1u; }", "m").unwrap();
        assert_eq!(
            kernel_features(none.kernel("k").unwrap()).global_atomics,
            AtomicsClass::None
        );
    }

    #[test]
    fn features_detect_team_ops() {
        let m = compile(
            "__global__ void k(unsigned* p) { p[0] = __ballot_sync(0u, true); }",
            "m",
        )
        .unwrap();
        let f = kernel_features(m.kernel("k").unwrap());
        assert!(f.has_team_ops);
        assert!(!f.has_barrier);
    }
}
