//! Event-graph stream executor — the runtime's scheduling seam and (since
//! API v2) the owner of every stream/event lifecycle.
//!
//! Streams are thin generational handles
//! ([`crate::runtime::stream::StreamHandle`]): recording a command —
//! launch, copy, cross-stream wait (marker), resume — appends a node to a
//! per-runtime DAG, and a small pool of executor threads drains **ready**
//! nodes onto the shared block-dispatch pool. The graph is the *single
//! source of stream identity*: there is no second host-side registry to
//! skew against it.
//!
//! Graph shape and the invariants it preserves:
//!
//! * **Per-stream FIFO.** Every node has an implicit dependency on its
//!   stream predecessor (streams are queues in the graph); a node is ready
//!   only when it is at the front of its stream. Cross-stream edges are
//!   explicit `deps` (recorded by wait-event-style marker nodes); a node
//!   additionally waits for those to reach a terminal state.
//! * **Halt semantics.** When a launch returns `Paused` (cooperative
//!   checkpoint), the stream *halts*: its queued nodes stay pending — the
//!   paper's "deferred until migration completes" — and only a `Resume`
//!   node (pushed to the queue front by [`EventGraph::resume`]) may run.
//!   Resume re-enters the kernel from its captured per-block state,
//!   possibly on a different device, then the deferred queue drains in the
//!   original FIFO order.
//! * **Sticky errors.** A failing node poisons its stream: nodes already
//!   queued behind it (and any recorded later) fail terminally — they can
//!   never execute, and leaving them queued would hang cross-stream
//!   waiters — while every `synchronize` keeps reporting the first error.
//!   Other streams are unaffected unless they wait on a failed event,
//!   which poisons them in turn.
//! * **Device overlap.** Executors run `RuntimeInner::run_launch`, which
//!   takes the device gate *shared* — independent launches overlap both
//!   across devices and on one device, sharing host cores through the
//!   dispatch-pool budget (`sim::dispatch::budget`).
//! * **Resource lifecycle.** Streams and events live in generational
//!   slot-reuse tables (`runtime::handle::SlotTable`):
//!   [`EventGraph::destroy_stream`] drains a stream, retires its events
//!   and frees its slot; [`EventGraph::retire_event`] drops the caller's
//!   hold on an event. A terminal event's entry is reclaimed as soon as it
//!   is *unreferenced* — neither held by its creator nor named as a
//!   pending node's dependency — so the status table is bounded by live
//!   handles, not by the total number of commands ever recorded. Stale
//!   handles of either type surface as `HetError::InvalidHandle`.
//!
//! Sharded launches (the multi-device coordinator) enter here too: a launch
//! node may carry a [`ShardRange`], which the executor lowers to per-block
//! resume directives (`Skip` outside the range) — the same mechanism
//! migration resume uses, so a shard can itself pause and be rebalanced.

use crate::coordinator::shard::ShardRange;
use crate::delta::journal::AtomicJournal;
use crate::error::{HetError, Result};
use crate::obs::Phase;
use crate::runtime::device::HealthState;
use crate::runtime::faultinject::FaultKind;
use crate::runtime::handle::{impl_handle_raw, SlotTable};
use crate::runtime::jit::JitMemo;
use crate::runtime::launch::LaunchSpec;
use crate::runtime::memory::{GpuPtr, PinnedBuffer};
use crate::runtime::stream::{PausedKernel, StreamHandle, StreamStats};
use crate::runtime::RuntimeInner;
use crate::sim::snapshot::{BlockResume, CostReport, LaunchOutcome};
use std::collections::VecDeque;
use std::sync::atomic;
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::Instant;

/// Generational handle to a recorded command node (CUDA-event-like).
///
/// Goes stale once the event is retired — explicitly via
/// `HetGpu::retire_event`, or implicitly when its stream is destroyed —
/// after which queries and waits return `HetError::InvalidHandle`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EventId {
    pub(crate) slot: u32,
    pub(crate) gen: u32,
}

impl_handle_raw!(EventId, "event");

/// Lifecycle of a graph node, observable via [`EventGraph::query`].
#[derive(Debug, Clone, PartialEq)]
pub enum EventStatus {
    /// Recorded, not yet picked by an executor (possibly deferred behind a
    /// halt or unsatisfied dependencies).
    Queued,
    Running,
    /// Executed. A launch that *paused* at a checkpoint is still
    /// `Completed` — the pause is stream state, not a node failure.
    Completed,
    Failed(String),
}

impl EventStatus {
    pub fn is_terminal(&self) -> bool {
        matches!(self, EventStatus::Completed | EventStatus::Failed(_))
    }
}

/// Live/allocated resource counts of the graph — the observability hook
/// the lifecycle tests (and long-running services) use to assert that
/// reclamation keeps the tables bounded by live handles.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GraphStats {
    /// Streams currently alive (created, not destroyed).
    pub live_streams: usize,
    /// Stream slots ever allocated (bounded by peak concurrent streams).
    pub stream_slots: usize,
    /// Event entries currently tracked (held or dependency-referenced).
    pub live_events: usize,
    /// Event slots ever allocated (bounded by peak concurrent events).
    pub event_slots: usize,
}

/// What a recorded command does when an executor picks it.
pub(crate) enum NodeKind {
    /// Kernel launch; `shard` restricts execution to a block range,
    /// `journal` engages the cross-shard atomics protocol (commutative
    /// global atomics append typed entries the coordinator's join
    /// replays; ordered ops fail closed), and `trace` is the
    /// observability root span this launch's spans parent under (0 when
    /// tracing was disarmed at record time).
    Launch {
        spec: LaunchSpec,
        shard: Option<ShardRange>,
        journal: Option<Arc<AtomicJournal>>,
        trace: u64,
    },
    /// Re-enter a paused kernel from its captured per-block state.
    Resume { paused: Box<PausedKernel> },
    /// Asynchronous host→device copy into unified memory (writes the
    /// allocation's resident device).
    CopyH2D { dst: GpuPtr, data: Vec<u8> },
    /// Asynchronous device→host copy out of the *stream's* device into a
    /// pinned host buffer.
    CopyD2H { src: GpuPtr, dst: PinnedBuffer },
    /// Peer copy: pull an address range from `src_device`'s arena into
    /// the stream's device arena (same unified address both sides).
    CopyPeer { ptr: GpuPtr, bytes: u64, src_device: usize },
    /// Cut a dirty-tracking epoch on the stream's device when the stream
    /// reaches this node, publishing the new epoch id into `out`. The
    /// coordinator records one between a shard's broadcast copies and its
    /// launch (per-stream FIFO makes that the exact boundary), so the
    /// shard's own writes are separable from the broadcast's.
    EpochCut { out: Arc<OnceLock<u64>> },
    /// No-op synchronization point (carries cross-stream `deps`).
    Marker,
}

struct Node {
    id: EventId,
    kind: NodeKind,
    /// Explicit cross-stream dependencies; the implicit same-stream
    /// predecessor edge is the queue order itself.
    deps: Vec<EventId>,
    /// When the node entered its stream queue — feeds the busy-vs-queued
    /// breakdown in [`StreamStats`] and, when tracing is armed, the
    /// graph-schedule span (enqueue → executor pickup).
    enqueued: Instant,
}

/// Provenance of a device fault that poisoned a stream, kept alongside
/// the sticky error string so recovery layers (the coordinator's fault
/// policies) can distinguish *device* faults — recoverable by re-placing
/// work — from semantic errors (bad args, ordered atomics) that would
/// fail identically anywhere.
#[derive(Debug, Clone)]
pub struct LostInfo {
    /// Runtime id of the device that faulted.
    pub device: usize,
    /// Device kind name as reported by the fault (e.g. `amd-sim`).
    pub device_name: String,
    /// Kernel that was executing, when known.
    pub kernel: Option<String>,
    /// Faulting thread block (lowest faulting linear id), when known.
    pub block: Option<u32>,
    /// Module uid of the faulting launch, when known.
    pub module_uid: Option<u64>,
    /// Underlying fault message.
    pub msg: String,
}

struct StreamState {
    device: usize,
    queue: VecDeque<Node>,
    /// An executor is currently running this stream's front node.
    running: bool,
    /// Halted at a checkpoint; queued nodes are deferred until `Resume`.
    halted: bool,
    sticky: Option<String>,
    /// Device-fault provenance when the sticky error was a device fault
    /// (first fault wins, like `sticky`).
    fault: Option<LostInfo>,
    paused: Option<PausedKernel>,
    stats: StreamStats,
    /// The stream's last `(module, kernel)` JIT resolution (launch
    /// batching: same-kernel repeats skip the shared cache). Shared with
    /// the executor via `Arc` so the graph lock is never held across a
    /// launch.
    jit_memo: Arc<Mutex<Option<JitMemo>>>,
}

/// One tracked event: its status plus the references that keep the entry
/// alive. Reclaimed (slot freed, generation bumped) once terminal,
/// un-held, and unreferenced by any pending node.
struct EventEntry {
    status: EventStatus,
    /// Pending nodes whose `deps` name this event.
    dep_refs: u32,
    /// Still held by its creator (not yet retired / stream not destroyed).
    held: bool,
    /// Slot of the stream the event was recorded on (retired in bulk when
    /// that stream is destroyed).
    stream_slot: u32,
}

struct GraphInner {
    streams: SlotTable<StreamState>,
    events: SlotTable<EventEntry>,
    shutdown: bool,
}

/// The per-runtime command DAG plus its executor pool's shared state.
pub struct EventGraph {
    rt: Arc<RuntimeInner>,
    inner: Mutex<GraphInner>,
    /// Single condvar for both edges: executors wait for ready nodes,
    /// `synchronize` waits for completions; every state change notifies all.
    cv: Condvar,
}

fn bad_stream() -> HetError {
    HetError::invalid_handle("stream", "stream was destroyed or never created")
}

fn bad_event() -> HetError {
    HetError::invalid_handle("event", "event was retired or never recorded")
}

/// Free an event's slot if nothing keeps it alive: terminal status, not
/// held, no pending dependency references.
fn try_reclaim(events: &mut SlotTable<EventEntry>, ev: EventId) {
    let reclaim = match events.get(ev.slot, ev.gen) {
        Some(e) => !e.held && e.dep_refs == 0 && e.status.is_terminal(),
        None => false,
    };
    if reclaim {
        events.remove(ev.slot, ev.gen);
    }
}

/// Drop a consumed node's dependency references (and reclaim what that
/// unpins).
fn release_deps(events: &mut SlotTable<EventEntry>, deps: &[EventId]) {
    for d in deps {
        if let Some(e) = events.get_mut(d.slot, d.gen) {
            e.dep_refs = e.dep_refs.saturating_sub(1);
        }
        try_reclaim(events, *d);
    }
}

impl EventGraph {
    pub fn new(rt: Arc<RuntimeInner>) -> Arc<EventGraph> {
        Arc::new(EventGraph {
            rt,
            inner: Mutex::new(GraphInner {
                streams: SlotTable::new(),
                events: SlotTable::new(),
                shutdown: false,
            }),
            cv: Condvar::new(),
        })
    }

    /// Start `n` executor threads draining `graph`.
    pub fn spawn_executors(graph: &Arc<EventGraph>, n: usize) -> Vec<JoinHandle<()>> {
        (0..n.max(1))
            .map(|i| {
                let g = Arc::clone(graph);
                std::thread::Builder::new()
                    .name(format!("hetgpu-exec-{i}"))
                    .spawn(move || executor_loop(&g))
                    .expect("spawn graph executor")
            })
            .collect()
    }

    /// Stop the executor pool (queued nodes are abandoned; contexts
    /// synchronize before dropping if they care).
    pub fn shutdown(&self) {
        self.inner.lock().unwrap().shutdown = true;
        self.cv.notify_all();
    }

    /// Register a new stream bound to `device`; returns its generational
    /// handle. Slots of destroyed streams are reused with a bumped
    /// generation, so stale handles stay detectable.
    pub fn add_stream(&self, device: usize) -> StreamHandle {
        let mut g = self.inner.lock().unwrap();
        let (slot, gen) = g.streams.insert(StreamState {
            device,
            queue: VecDeque::new(),
            running: false,
            halted: false,
            sticky: None,
            fault: None,
            paused: None,
            stats: StreamStats::default(),
            jit_memo: Arc::new(Mutex::new(None)),
        });
        StreamHandle::new(slot, gen)
    }

    /// Destroy a stream: wait for its queue to drain (sticky errors are
    /// fine — a poisoned stream's queue is already cleared), retire every
    /// event still held on it, and free its slot. A stream halted at a
    /// checkpoint refuses destruction (its captured kernel would be lost);
    /// resume it first. Double-destroy and stale handles return
    /// `HetError::InvalidHandle`.
    pub fn destroy_stream(&self, stream: StreamHandle) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        loop {
            let st = g.streams.get(stream.slot, stream.gen).ok_or_else(bad_stream)?;
            if st.halted {
                return Err(HetError::runtime(
                    "cannot destroy a stream halted at a checkpoint; resume it first",
                ));
            }
            if g.shutdown || (!st.running && st.queue.is_empty()) {
                break;
            }
            g = self.cv.wait(g).unwrap();
        }
        // Retire everything recorded on this stream. Terminal, unreferenced
        // entries free immediately; entries still named by other streams'
        // pending deps linger only until those nodes consume them.
        for slot in 0..g.events.slot_count() as u32 {
            let reclaim = match g.events.entry_at_mut(slot) {
                Some(e) if e.stream_slot == stream.slot && e.held => {
                    e.held = false;
                    e.dep_refs == 0 && e.status.is_terminal()
                }
                _ => false,
            };
            if reclaim {
                g.events.remove_at(slot);
            }
        }
        g.streams.remove(stream.slot, stream.gen);
        drop(g);
        self.cv.notify_all();
        Ok(())
    }

    /// Drop the caller's hold on an event. Its entry is reclaimed once
    /// terminal and unreferenced; afterwards (and for double-retires) the
    /// handle is stale and returns `HetError::InvalidHandle`.
    pub fn retire_event(&self, ev: EventId) -> Result<()> {
        let mut g = self.inner.lock().unwrap();
        let e = g.events.get_mut(ev.slot, ev.gen).ok_or_else(bad_event)?;
        if !e.held {
            return Err(HetError::invalid_handle("event", "event already retired"));
        }
        e.held = false;
        try_reclaim(&mut g.events, ev);
        Ok(())
    }

    /// Record a command node at the back of `stream`'s queue. Each `deps`
    /// entry must name a live event (a retired one is a stale handle) and
    /// pins it until this node reaches a terminal state.
    pub(crate) fn enqueue(
        &self,
        stream: StreamHandle,
        kind: NodeKind,
        deps: &[EventId],
    ) -> Result<EventId> {
        let mut g = self.inner.lock().unwrap();
        if g.shutdown {
            return Err(HetError::runtime("runtime is shutting down"));
        }
        let sticky = {
            let st = g.streams.get(stream.slot, stream.gen).ok_or_else(bad_stream)?;
            st.sticky.is_some()
        };
        // Stale dependency handles are rejected regardless of stream
        // health — the InvalidHandle contract must not become
        // state-dependent on a poisoned stream.
        for d in deps {
            g.events.get(d.slot, d.gen).ok_or_else(bad_event)?;
        }
        // A poisoned stream never runs another node; record the event as
        // terminally failed (rather than queued-forever) so cross-stream
        // waiters observe a terminal state. The sticky error still
        // surfaces at this stream's synchronize.
        let status = if sticky {
            EventStatus::Failed("stream poisoned by earlier error".into())
        } else {
            EventStatus::Queued
        };
        let (slot, gen) = g.events.insert(EventEntry {
            status,
            dep_refs: 0,
            held: true,
            stream_slot: stream.slot,
        });
        let id = EventId { slot, gen };
        if !sticky {
            for d in deps {
                g.events.get_mut(d.slot, d.gen).expect("validated above").dep_refs += 1;
            }
            g.streams
                .get_mut(stream.slot, stream.gen)
                .expect("validated above")
                .queue
                .push_back(Node { id, kind, deps: deps.to_vec(), enqueued: Instant::now() });
        }
        drop(g);
        self.cv.notify_all();
        Ok(id)
    }

    /// Record a batch of dependency-less command nodes at the back of
    /// `stream`'s queue under **one** graph lock acquisition and **one**
    /// executor wake-up — N `enqueue` calls pay N lock hand-offs and N
    /// condvar notifies; a batch pays one of each (the `record_batch`
    /// rung of launch batching). Stream semantics are unchanged: the
    /// nodes run in order, exactly as if recorded one at a time.
    pub(crate) fn enqueue_batch(
        &self,
        stream: StreamHandle,
        kinds: Vec<NodeKind>,
    ) -> Result<Vec<EventId>> {
        let mut g = self.inner.lock().unwrap();
        if g.shutdown {
            return Err(HetError::runtime("runtime is shutting down"));
        }
        let sticky = {
            let st = g.streams.get(stream.slot, stream.gen).ok_or_else(bad_stream)?;
            st.sticky.is_some()
        };
        let mut ids = Vec::with_capacity(kinds.len());
        for kind in kinds {
            let status = if sticky {
                EventStatus::Failed("stream poisoned by earlier error".into())
            } else {
                EventStatus::Queued
            };
            let (slot, gen) = g.events.insert(EventEntry {
                status,
                dep_refs: 0,
                held: true,
                stream_slot: stream.slot,
            });
            let id = EventId { slot, gen };
            if !sticky {
                g.streams
                    .get_mut(stream.slot, stream.gen)
                    .expect("validated above")
                    .queue
                    .push_back(Node { id, kind, deps: Vec::new(), enqueued: Instant::now() });
            }
            ids.push(id);
        }
        drop(g);
        self.cv.notify_all();
        Ok(ids)
    }

    /// Status of a recorded event; stale handles (retired events) return
    /// `HetError::InvalidHandle`.
    pub fn query(&self, ev: EventId) -> Result<EventStatus> {
        self.inner
            .lock()
            .unwrap()
            .events
            .get(ev.slot, ev.gen)
            .map(|e| e.status.clone())
            .ok_or_else(bad_event)
    }

    pub fn stream_device(&self, stream: StreamHandle) -> Result<usize> {
        let g = self.inner.lock().unwrap();
        g.streams.get(stream.slot, stream.gen).map(|s| s.device).ok_or_else(bad_stream)
    }

    pub fn stats(&self, stream: StreamHandle) -> Result<StreamStats> {
        let g = self.inner.lock().unwrap();
        g.streams
            .get(stream.slot, stream.gen)
            .map(|s| s.stats.clone())
            .ok_or_else(bad_stream)
    }

    /// Device-fault provenance of a poisoned stream, if the poisoning
    /// error was a device fault. `None` means the stream is healthy or
    /// failed for a non-device reason (recovery must not retry those).
    pub fn stream_fault(&self, stream: StreamHandle) -> Result<Option<LostInfo>> {
        let g = self.inner.lock().unwrap();
        g.streams
            .get(stream.slot, stream.gen)
            .map(|s| s.fault.clone())
            .ok_or_else(bad_stream)
    }

    /// Clear a stream's sticky error so it can run again — the recovery
    /// path for fault policies: the poison already drained the queue
    /// (stranded nodes failed terminally), so after the reset the stream
    /// is empty and re-recorded work executes normally. Accumulated
    /// stats survive (failed launches never recorded any). Refuses on a
    /// halted or busy stream.
    pub fn reset_stream(&self, stream: StreamHandle) -> Result<()> {
        {
            let mut g = self.inner.lock().unwrap();
            let st = g.streams.get_mut(stream.slot, stream.gen).ok_or_else(bad_stream)?;
            if st.halted {
                return Err(HetError::runtime(
                    "cannot reset a stream halted at a checkpoint; resume it first",
                ));
            }
            if st.running || !st.queue.is_empty() {
                return Err(HetError::runtime("cannot reset a busy stream; synchronize first"));
            }
            st.sticky = None;
            st.fault = None;
        }
        self.cv.notify_all();
        Ok(())
    }

    /// Live/allocated counts of both handle tables.
    pub fn graph_stats(&self) -> GraphStats {
        let g = self.inner.lock().unwrap();
        GraphStats {
            live_streams: g.streams.live(),
            stream_slots: g.streams.slot_count(),
            live_events: g.events.live(),
            event_slots: g.events.slot_count(),
        }
    }

    /// Wait until the stream can make no further progress: its queue is
    /// drained, or blocked by a halt / sticky error. Reports the sticky
    /// error if any; leaves deferred nodes queued (they run after resume).
    pub fn synchronize(&self, stream: StreamHandle) -> Result<()> {
        self.wait_idle(stream).map(|_halted| ())
    }

    /// Like [`EventGraph::synchronize`], additionally reporting whether the
    /// stream is halted at a checkpoint (the migration orchestrator asks).
    pub fn quiesce(&self, stream: StreamHandle) -> Result<bool> {
        self.wait_idle(stream)
    }

    fn wait_idle(&self, stream: StreamHandle) -> Result<bool> {
        let mut g = self.inner.lock().unwrap();
        loop {
            let st = g.streams.get(stream.slot, stream.gen).ok_or_else(bad_stream)?;
            // A halted stream still makes progress through a front `Resume`
            // node (the re-entry the orchestrator just recorded), so only a
            // halt with ordinary deferred work counts as blocked.
            let front_resume = st
                .queue
                .front()
                .map(|n| matches!(n.kind, NodeKind::Resume { .. }))
                .unwrap_or(false);
            let blocked = st.sticky.is_some() || (st.halted && !front_resume);
            if !st.running && (st.queue.is_empty() || blocked) {
                return match &st.sticky {
                    Some(e) => Err(HetError::runtime(format!("{stream}: {e}"))),
                    None => Ok(st.halted),
                };
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Take the paused kernel (leaves the stream halted until resume).
    pub fn take_paused(&self, stream: StreamHandle) -> Result<Option<PausedKernel>> {
        let mut g = self.inner.lock().unwrap();
        g.streams
            .get_mut(stream.slot, stream.gen)
            .map(|s| s.paused.take())
            .ok_or_else(bad_stream)
    }

    /// Rebind the stream to `device` and re-enter the restored kernel (or
    /// just un-halt when `paused` is `None`). The target device is
    /// validated *before* anything is acknowledged — an invalid id errors
    /// here, at the resume call, never as a later sticky stream error. The
    /// re-entry itself runs asynchronously on the executor pool (the
    /// chained H100→AMD→Tenstorrent scenario of §6.3 triggers the next
    /// checkpoint while it runs); its failures become sticky errors.
    pub fn resume(
        &self,
        stream: StreamHandle,
        device: usize,
        paused: Option<PausedKernel>,
    ) -> Result<()> {
        self.rt.device(device)?; // validate before acking
        {
            let mut guard = self.inner.lock().unwrap();
            let inner = &mut *guard;
            let st = inner
                .streams
                .get_mut(stream.slot, stream.gen)
                .ok_or_else(bad_stream)?;
            st.device = device;
            match paused {
                Some(pk) => {
                    // Jump the deferred queue: re-entry precedes every
                    // command deferred while the stream was halted. The
                    // internal event is *not* held — its id is never
                    // handed out, so it must self-reclaim on completion
                    // or a migration loop would grow the event table.
                    let (slot, gen) = inner.events.insert(EventEntry {
                        status: EventStatus::Queued,
                        dep_refs: 0,
                        held: false,
                        stream_slot: stream.slot,
                    });
                    st.queue.push_front(Node {
                        id: EventId { slot, gen },
                        kind: NodeKind::Resume { paused: Box::new(pk) },
                        deps: Vec::new(),
                        enqueued: Instant::now(),
                    });
                }
                None => st.halted = false,
            }
        }
        self.cv.notify_all();
        Ok(())
    }

    /// Resume-in-place every stream on `device` (except `exclude`) that
    /// was collaterally halted by the device-wide pause flag. The
    /// checkpoint protocol pauses a whole device, and with launches
    /// overlapping on one device an unrelated stream's kernel can observe
    /// the flag and halt too; nothing would ever resume it, and its
    /// deferred work would silently never run. Callers invoke this after
    /// the capture window (the exclusive device gate has been released, so
    /// every launch that observed the flag has already halted); captured
    /// kernels re-enter on their own device and deferred queues drain.
    pub fn resume_collateral(&self, device: usize, exclude: StreamHandle) {
        {
            let mut guard = self.inner.lock().unwrap();
            // A stream whose launch just returned Paused may not have had
            // its halt folded into the graph yet (the executor publishes
            // after releasing the device gate) — wait for every running
            // node on this device to settle so no collateral halt is
            // missed.
            loop {
                let mut busy = false;
                for si in 0..guard.streams.slot_count() as u32 {
                    if si == exclude.slot {
                        continue;
                    }
                    if let Some(st) = guard.streams.entry_at(si) {
                        if st.device == device && st.running {
                            busy = true;
                            break;
                        }
                    }
                }
                if !busy || guard.shutdown {
                    break;
                }
                guard = self.cv.wait(guard).unwrap();
            }
            let inner = &mut *guard;
            for si in 0..inner.streams.slot_count() as u32 {
                if si == exclude.slot {
                    continue;
                }
                let Some(st) = inner.streams.entry_at_mut(si) else { continue };
                if st.device != device || !st.halted {
                    continue;
                }
                match st.paused.take() {
                    Some(pk) => {
                        // Internal, never handed out: not held (see
                        // `resume`), so it self-reclaims on completion.
                        let (slot, gen) = inner.events.insert(EventEntry {
                            status: EventStatus::Queued,
                            dep_refs: 0,
                            held: false,
                            stream_slot: si,
                        });
                        st.queue.push_front(Node {
                            id: EventId { slot, gen },
                            kind: NodeKind::Resume { paused: Box::new(pk) },
                            deps: Vec::new(),
                            enqueued: Instant::now(),
                        });
                    }
                    // Halted with its capture already harvested elsewhere:
                    // nothing to re-enter, just unblock the queue.
                    None => st.halted = false,
                }
            }
        }
        self.cv.notify_all();
    }
}

/// Outcome of executing one node, before it is folded back into the graph.
enum Exec {
    Launch {
        cost: CostReport,
        wall_us: f64,
        workers: usize,
        completed: bool,
        paused: Option<PausedKernel>,
    },
    Plain,
}

/// Pick a ready node: front of a non-running, non-poisoned stream, with
/// all explicit deps terminal; a halted stream only offers `Resume`. The
/// returned flag is true when a dependency *failed* — the caller must
/// fail the node without executing it (a cross-stream edge from a failed
/// producer must poison the consumer, not silently satisfy it).
#[allow(clippy::type_complexity)]
fn take_ready(g: &mut GraphInner) -> Option<(u32, usize, Node, bool, Arc<Mutex<Option<JitMemo>>>)> {
    for si in 0..g.streams.slot_count() as u32 {
        let dep_failed = {
            let Some(st) = g.streams.entry_at(si) else { continue };
            if st.running || st.sticky.is_some() || st.queue.is_empty() {
                continue;
            }
            let front = st.queue.front().unwrap();
            if st.halted && !matches!(front.kind, NodeKind::Resume { .. }) {
                continue;
            }
            let mut dep_failed = false;
            let mut deps_terminal = true;
            for d in &front.deps {
                // A pinned dep cannot be reclaimed while referenced, so a
                // missing entry is unreachable via the public API; treat
                // it as satisfied.
                match g.events.get(d.slot, d.gen).map(|e| &e.status) {
                    Some(EventStatus::Failed(_)) => dep_failed = true,
                    Some(s) if !s.is_terminal() => deps_terminal = false,
                    _ => {}
                }
            }
            if !deps_terminal {
                continue;
            }
            dep_failed
        };
        let st = g.streams.entry_at_mut(si).expect("checked above");
        let device = st.device;
        let node = st.queue.pop_front().unwrap();
        st.running = true;
        let memo = Arc::clone(&st.jit_memo);
        if let Some(e) = g.events.get_mut(node.id.slot, node.id.gen) {
            e.status = EventStatus::Running;
        }
        return Some((si, device, node, dep_failed, memo));
    }
    None
}

fn executor_loop(g: &EventGraph) {
    loop {
        let (si, device, node, dep_failed, memo) = {
            let mut inner = g.inner.lock().unwrap();
            loop {
                if inner.shutdown {
                    return;
                }
                if let Some(t) = take_ready(&mut inner) {
                    break t;
                }
                inner = g.cv.wait(inner).unwrap();
            }
        };

        // Queued time (enqueue → pickup) is the always-on half of the
        // busy-vs-queued stream stats breakdown; the observability spans
        // below only materialize while tracing is armed.
        let queued_us = node.enqueued.elapsed().as_secs_f64() * 1e6;
        let trace = match &node.kind {
            NodeKind::Launch { trace, .. } => *trace,
            NodeKind::Resume { paused } => paused.trace,
            _ => 0,
        };

        let result = if dep_failed {
            Err(HetError::runtime("awaited event failed"))
        } else {
            let is_launch = matches!(node.kind, NodeKind::Launch { .. } | NodeKind::Resume { .. });
            if is_launch && g.rt.obs.armed() {
                // The graph-schedule span covers the node's queued life:
                // enqueue (record) to the moment this executor picked it.
                g.rt.obs.span_since(
                    node.enqueued,
                    trace,
                    Phase::GraphSchedule,
                    &launch_label(&node.kind),
                    Some(device),
                );
            }
            let d_span = if is_launch { g.rt.obs.begin() } else { None };
            let parent_span = d_span.map_or(0, |s| s.id);
            let mut result = execute_node(&g.rt, device, &node.kind, &memo, parent_span);
            // Copies are idempotent (same source bytes, same destination
            // range), so a device fault during one — a flaky link, an
            // injected transient — is retried in place instead of
            // poisoning the stream; cross-stream waiters then observe
            // Completed and unblock. Launches are NOT retried here: a
            // faulted launch may have committed partial writes, and only
            // the coordinator knows how to discard those against a
            // baseline.
            if matches!(
                node.kind,
                NodeKind::CopyH2D { .. } | NodeKind::CopyD2H { .. } | NodeKind::CopyPeer { .. }
            ) {
                let mut attempts = 1;
                while attempts < 3
                    && matches!(&result, Err(e) if e.is_device_fault())
                {
                    g.rt.fault.counters.retries.fetch_add(1, atomic::Ordering::Relaxed);
                    result = execute_node(&g.rt, device, &node.kind, &memo, parent_span);
                    attempts += 1;
                }
                if attempts > 1 && result.is_ok() {
                    // Recovered after a fault: the device works but is
                    // suspect.
                    if let Ok(d) = g.rt.device(device) {
                        if d.health() == HealthState::Healthy {
                            d.set_health(HealthState::Degraded);
                        }
                    }
                }
            }
            if let Some(s) = d_span {
                g.rt.obs.end(s, trace, Phase::Dispatch, &launch_label(&node.kind), Some(device));
            }
            result
        };

        {
            let mut guard = g.inner.lock().unwrap();
            // Split the guard once so stream and event borrows are
            // disjoint field projections.
            let inner = &mut *guard;
            // The stream is pinned by its running node except during a
            // shutdown teardown, where `destroy_stream` may free it
            // without waiting — tolerate a vanished slot rather than
            // panicking an executor.
            match result {
                Ok(Exec::Launch { cost, wall_us, workers, completed, paused }) => {
                    if let Some(st) = inner.streams.entry_at_mut(si) {
                        st.running = false;
                        st.stats
                            .record_launch(device, workers, wall_us, queued_us, &cost, completed);
                        if let Some(mut pk) = paused {
                            // Stamp the launch's root span so the spans of
                            // the eventual resume join the same tree.
                            pk.trace = trace;
                            st.paused = Some(pk);
                            st.halted = true;
                        } else if matches!(node.kind, NodeKind::Resume { .. }) {
                            st.halted = false;
                        }
                    }
                    if let Some(e) = inner.events.get_mut(node.id.slot, node.id.gen) {
                        e.status = EventStatus::Completed;
                    }
                }
                Ok(Exec::Plain) => {
                    if let Some(st) = inner.streams.entry_at_mut(si) {
                        st.running = false;
                    }
                    if let Some(e) = inner.events.get_mut(node.id.slot, node.id.gen) {
                        e.status = EventStatus::Completed;
                    }
                }
                Err(e) => {
                    let msg = e.to_string();
                    // Device faults keep typed provenance alongside the
                    // sticky string so recovery layers can tell "this
                    // device broke" from "this program is wrong".
                    let lost = match &e {
                        HetError::DeviceFault { device: name, msg, ctx } => Some(LostInfo {
                            device,
                            device_name: name.clone(),
                            kernel: ctx.kernel.clone(),
                            block: ctx.block,
                            module_uid: ctx.module_uid,
                            msg: msg.clone(),
                        }),
                        _ => None,
                    };
                    if lost.is_some() {
                        g.rt.fault.counters.observed.fetch_add(1, atomic::Ordering::Relaxed);
                    }
                    // Everything deferred behind the poison will never
                    // run; fail those nodes now so cross-stream waiters
                    // (wait_event deps) reach a terminal state instead of
                    // hanging on events that can no longer happen.
                    let stranded: Vec<Node> = match inner.streams.entry_at_mut(si) {
                        Some(st) => {
                            st.running = false;
                            st.sticky.get_or_insert(msg.clone());
                            if st.fault.is_none() {
                                st.fault = lost;
                            }
                            st.queue.drain(..).collect()
                        }
                        None => Vec::new(),
                    };
                    if let Some(en) = inner.events.get_mut(node.id.slot, node.id.gen) {
                        en.status = EventStatus::Failed(msg);
                    }
                    for n in stranded {
                        if let Some(en) = inner.events.get_mut(n.id.slot, n.id.gen) {
                            en.status =
                                EventStatus::Failed("stream poisoned by earlier error".into());
                        }
                        release_deps(&mut inner.events, &n.deps);
                        try_reclaim(&mut inner.events, n.id);
                    }
                }
            }
            // The node is terminal either way: release its dependency pins
            // and reclaim whatever became unreferenced (including the node
            // itself, if its creator already retired it).
            release_deps(&mut inner.events, &node.deps);
            try_reclaim(&mut inner.events, node.id);
        }
        g.cv.notify_all();
    }
}

/// Human-readable span label of a launch-shaped node: kernel name, plus
/// the shard range for coordinator shards and a `resume` prefix for
/// re-entered kernels. Only called while tracing is armed (it allocates).
fn launch_label(kind: &NodeKind) -> String {
    match kind {
        NodeKind::Launch { spec, shard: Some(r), .. } => {
            format!("{} [{}..{})", spec.kernel, r.lo, r.hi)
        }
        NodeKind::Launch { spec, .. } => spec.kernel.clone(),
        NodeKind::Resume { paused } => format!("resume {}", paused.spec.kernel),
        _ => String::new(),
    }
}

/// Lower a shard range to per-block resume directives: blocks outside the
/// range are `Skip`ped (committed as `Done` without running).
pub(crate) fn shard_directives(grid_size: u32, range: ShardRange) -> Vec<BlockResume> {
    (0..grid_size)
        .map(|b| if range.contains(b) { BlockResume::FromEntry } else { BlockResume::Skip })
        .collect()
}

/// Checked end-of-copy address: `addr + len`, failing closed on wrap —
/// the u64-overflow fix for copy bounds checks (addresses near
/// `u64::MAX` previously wrapped past the `base + size` comparison).
pub(crate) fn copy_end(addr: u64, len: u64, what: &str) -> Result<u64> {
    addr.checked_add(len)
        .ok_or_else(|| HetError::runtime(format!("{what} copy out of bounds (address overflow)")))
}

fn execute_node(
    rt: &RuntimeInner,
    device: usize,
    kind: &NodeKind,
    memo: &Mutex<Option<JitMemo>>,
    parent_span: u64,
) -> Result<Exec> {
    match kind {
        NodeKind::Launch { spec, shard, journal, .. } => {
            // The fault plane speaks in block offsets *relative to the
            // executed range* (it cannot know shard ranges); the executor
            // — which does — resolves the absolute faulting block here.
            // Skip-directive blocks outside a shard's range never run, so
            // an unresolved absolute id might never fire.
            let fault_off = rt.fault.launch_fault(device);
            let dirs = match shard {
                Some(r) => {
                    let (grid_size, _) = spec.dims.validate()?;
                    if r.is_empty() || r.hi > grid_size {
                        return Err(HetError::runtime(format!(
                            "shard range {}..{} outside grid of {grid_size} blocks",
                            r.lo, r.hi
                        )));
                    }
                    Some(shard_directives(grid_size, *r))
                }
                None => None,
            };
            let fault = fault_off.map(|off| match shard {
                Some(r) => r.lo.saturating_add(off).min(r.hi.saturating_sub(1)),
                None => off,
            });
            let dirs = dirs.as_deref();
            run_timed(rt, device, spec, dirs, journal.as_ref(), memo, None, fault, parent_span)
        }
        NodeKind::Resume { paused } => {
            let dirs = paused.resume_directives();
            // A same-device resume runs the pinned translation the kernel
            // was suspended under — a tier-2 swap while it was paused must
            // not change the program its captured registers re-enter. A
            // cross-device resume re-translates for the new target.
            let pinned = if paused.device == device { paused.prog.as_ref() } else { None };
            // A resumed journaled shard keeps journaling into the same
            // journal (carried inside the paused kernel), so entries of
            // re-entered blocks append behind their pre-pause batches.
            let journal = paused.journal.as_ref();
            let spec = &paused.spec;
            run_timed(rt, device, spec, Some(&dirs), journal, memo, pinned, None, parent_span)
        }
        NodeKind::CopyH2D { dst, data } => {
            let (base, size, dev_id) = rt.memory.lookup(*dst)?;
            if copy_end(dst.0, data.len() as u64, "h2d")? > base.saturating_add(size) {
                return Err(HetError::runtime("h2d copy out of bounds"));
            }
            let dev = rt.device(dev_id)?;
            let _gate = dev.exec.read().unwrap();
            dev.mem.write_bytes(dst.0, data)?;
            Ok(Exec::Plain)
        }
        NodeKind::CopyD2H { src, dst } => {
            if let Some(msg) = rt.fault.copy_fault(device, FaultKind::D2h) {
                return Err(HetError::fault(rt.device(device)?.kind.name(), msg));
            }
            // Reads the *stream's* device (not the residency table): a
            // coordinator shard's stream is bound to the device actually
            // holding the shard's image, including after a rebalance.
            let (base, size, _home) = rt.memory.lookup(*src)?;
            if copy_end(src.0, dst.len() as u64, "d2h")? > base.saturating_add(size) {
                return Err(HetError::runtime("d2h copy out of bounds"));
            }
            let dev = rt.device(device)?;
            let _gate = dev.exec.read().unwrap();
            dst.fill_from(&dev.mem, src.0)?;
            Ok(Exec::Plain)
        }
        NodeKind::CopyPeer { ptr, bytes, src_device } => {
            if let Some(msg) = rt.fault.copy_fault(device, FaultKind::Broadcast) {
                return Err(HetError::fault(rt.device(device)?.kind.name(), msg));
            }
            let (base, size, _home) = rt.memory.lookup(*ptr)?;
            if copy_end(ptr.0, *bytes, "peer")? > base.saturating_add(size) {
                return Err(HetError::runtime("peer copy out of bounds"));
            }
            let mut tmp = vec![0u8; *bytes as usize];
            {
                let src = rt.device(*src_device)?;
                let _gate = src.exec.read().unwrap();
                src.mem.read_bytes_into(ptr.0, &mut tmp)?;
            }
            let dst = rt.device(device)?;
            let _gate = dst.exec.read().unwrap();
            dst.mem.write_bytes(ptr.0, &tmp)?;
            Ok(Exec::Plain)
        }
        NodeKind::EpochCut { out } => {
            let dev = rt.device(device)?;
            let _ = out.set(dev.mem.dirty_epoch_cut());
            Ok(Exec::Plain)
        }
        NodeKind::Marker => Ok(Exec::Plain),
    }
}

#[allow(clippy::too_many_arguments)]
fn run_timed(
    rt: &RuntimeInner,
    device: usize,
    spec: &LaunchSpec,
    resume: Option<&[BlockResume]>,
    journal: Option<&Arc<AtomicJournal>>,
    memo: &Mutex<Option<JitMemo>>,
    pinned: Option<&Arc<crate::backends::DeviceProgram>>,
    fault: Option<u32>,
    parent_span: u64,
) -> Result<Exec> {
    let t0 = Instant::now();
    let (outcome, prog) = rt.run_launch(
        device,
        spec,
        resume,
        journal.map(|j| j.as_ref()),
        Some(memo),
        pinned,
        fault,
        parent_span,
    )?;
    let wall_us = t0.elapsed().as_secs_f64() * 1e6;
    let workers = rt.device(device).map(|d| d.engine.workers()).unwrap_or(1);
    let cost = *outcome.cost();
    // Move the captured block states out (they can be every thread's
    // registers plus shared memory — cloning them would sit directly in
    // the checkpoint latency path).
    let (completed, paused) = match outcome {
        LaunchOutcome::Completed(_) => (true, None),
        LaunchOutcome::Paused { grid, .. } => (
            false,
            Some(PausedKernel {
                spec: spec.clone(),
                blocks: grid.blocks,
                journal: journal.cloned(),
                device,
                // Pin the translation the kernel suspended under: a
                // same-device resume re-enters exactly this program even
                // if the tiered JIT swaps the cache entry meanwhile.
                prog: Some(prog),
                // The executor fold stamps the real root span id; the
                // timed runner doesn't know it.
                trace: 0,
            }),
        ),
    };
    Ok(Exec::Launch { cost, wall_us, workers, completed, paused })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::api::HetGpu;
    use crate::runtime::device::DeviceKind;
    use crate::runtime::launch::Arg;
    use crate::sim::simt::LaunchDims;

    const BUMP_SRC: &str = r#"
__global__ void bump(float* p) {
    unsigned i = blockIdx.x * blockDim.x + threadIdx.x;
    p[i] = p[i] + 1.0f;
}
"#;

    #[test]
    fn event_lifecycle_and_query() {
        let ctx = HetGpu::with_devices(&[DeviceKind::NvidiaSim]).unwrap();
        let m = ctx.compile_cuda(BUMP_SRC).unwrap();
        let buf = ctx.alloc_buffer::<f32>(64, 0).unwrap();
        ctx.upload(&buf, &[0.0; 64]).unwrap();
        let s = ctx.create_stream(0).unwrap();
        let ev = ctx
            .launch(m, "bump")
            .dims(LaunchDims::d1(2, 32))
            .arg(buf.arg())
            .record(s)
            .unwrap();
        ctx.synchronize(s).unwrap();
        assert_eq!(ctx.event_query(ev).unwrap(), EventStatus::Completed);
        let err = ctx.event_query(EventId::from_raw(u64::MAX)).unwrap_err();
        assert!(err.is_invalid_handle(), "{err}");
    }

    #[test]
    fn sticky_error_defers_later_work_and_reports_at_sync() {
        let ctx = HetGpu::with_devices(&[DeviceKind::NvidiaSim]).unwrap();
        let m = ctx.compile_cuda(BUMP_SRC).unwrap();
        let buf = ctx.alloc_buffer::<f32>(64, 0).unwrap();
        let s = ctx.create_stream(0).unwrap();
        // Wrong arg count fails inside the executor -> sticky.
        let bad = ctx.launch(m, "bump").dims(LaunchDims::d1(2, 32)).record(s).unwrap();
        let after = ctx
            .launch(m, "bump")
            .dims(LaunchDims::d1(2, 32))
            .arg(buf.arg())
            .record(s)
            .unwrap();
        assert!(ctx.synchronize(s).is_err());
        assert!(matches!(ctx.event_query(bad).unwrap(), EventStatus::Failed(_)));
        // The launch deferred behind the failure never ran — it fails
        // terminally (so nothing can hang waiting on it) instead of
        // staying queued forever.
        assert!(matches!(ctx.event_query(after).unwrap(), EventStatus::Failed(_)));
        // Sticky errors stay sticky, including for newly recorded work.
        assert!(ctx.synchronize(s).is_err());
        let late = ctx
            .launch(m, "bump")
            .dims(LaunchDims::d1(2, 32))
            .arg(buf.arg())
            .record(s)
            .unwrap();
        assert!(matches!(ctx.event_query(late).unwrap(), EventStatus::Failed(_)));
        assert!(ctx.synchronize(s).is_err());
    }

    #[test]
    fn resume_rejects_invalid_device_before_ack() {
        let ctx = HetGpu::with_devices(&[DeviceKind::NvidiaSim]).unwrap();
        let s = ctx.create_stream(0).unwrap();
        // Surfaces immediately, not as a later sticky stream error.
        let err = ctx.graph().resume(s, 7, None).unwrap_err();
        assert!(err.to_string().contains("no device 7"), "{err}");
        ctx.synchronize(s).unwrap();
    }

    #[test]
    fn cross_stream_marker_orders_work() {
        let ctx = HetGpu::with_devices(&[DeviceKind::NvidiaSim]).unwrap();
        let m = ctx
            .compile_cuda(
                r#"
__global__ void produce(unsigned* p, unsigned iters) {
    unsigned acc = 0u;
    for (unsigned k = 0u; k < iters; k++) { acc = acc + 1u; }
    if (threadIdx.x == 0u && blockIdx.x == 0u) p[1] = acc;
}
__global__ void consume(unsigned* p) {
    if (threadIdx.x == 0u && blockIdx.x == 0u) p[2] = p[1] * 10u;
}
"#,
            )
            .unwrap();
        // Stream b waits on a's (slow) producer event, so the consumer must
        // observe p[1] — without the edge it would read 0.
        let buf = ctx.alloc_buffer::<u32>(16, 0).unwrap();
        ctx.upload(&buf, &[0; 16]).unwrap();
        let a = ctx.create_stream(0).unwrap();
        let b = ctx.create_stream(0).unwrap();
        let ev = ctx
            .launch(m, "produce")
            .dims(LaunchDims::d1(1, 32))
            .args(&[buf.arg(), Arg::U32(50_000)])
            .record(a)
            .unwrap();
        ctx.wait_event(b, ev).unwrap();
        ctx.launch(m, "consume").dims(LaunchDims::d1(1, 32)).arg(buf.arg()).record(b).unwrap();
        ctx.synchronize(b).unwrap();
        ctx.synchronize(a).unwrap();
        let got = ctx.download(&buf, 3).unwrap();
        assert_eq!(got[1], 50_000);
        assert_eq!(got[2], 500_000, "consumer ran before the awaited producer");
    }

    #[test]
    fn failed_dependency_poisons_waiting_stream() {
        let ctx = HetGpu::with_devices(&[DeviceKind::NvidiaSim]).unwrap();
        let m = ctx.compile_cuda(BUMP_SRC).unwrap();
        let buf = ctx.alloc_buffer::<f32>(64, 0).unwrap();
        let a = ctx.create_stream(0).unwrap();
        let b = ctx.create_stream(0).unwrap();
        // Wrong arg count: the producer launch fails in the executor.
        let bad = ctx.launch(m, "bump").dims(LaunchDims::d1(2, 32)).record(a).unwrap();
        ctx.wait_event(b, bad).unwrap();
        let after = ctx
            .launch(m, "bump")
            .dims(LaunchDims::d1(2, 32))
            .arg(buf.arg())
            .record(b)
            .unwrap();
        // The cross-stream edge must carry the failure, not satisfy it.
        assert!(ctx.synchronize(b).is_err());
        assert!(matches!(ctx.event_query(after).unwrap(), EventStatus::Failed(_)));
        assert!(ctx.synchronize(a).is_err());
    }

    #[test]
    fn async_h2d_copy_is_fifo_with_launches() {
        let ctx = HetGpu::with_devices(&[DeviceKind::NvidiaSim]).unwrap();
        let m = ctx.compile_cuda(BUMP_SRC).unwrap();
        let buf = ctx.alloc_buffer::<f32>(64, 0).unwrap();
        let s = ctx.create_stream(0).unwrap();
        let init: Vec<u8> = [5.0f32; 64].iter().flat_map(|v| v.to_le_bytes()).collect();
        ctx.memcpy_h2d_async(s, buf.ptr(), &init).unwrap();
        ctx.launch(m, "bump").dims(LaunchDims::d1(2, 32)).arg(buf.arg()).record(s).unwrap();
        ctx.synchronize(s).unwrap();
        assert!(ctx.download(&buf, 64).unwrap().iter().all(|v| *v == 6.0));
    }

    #[test]
    fn async_d2h_copy_into_pinned_buffer() {
        let ctx = HetGpu::with_devices(&[DeviceKind::NvidiaSim]).unwrap();
        let m = ctx.compile_cuda(BUMP_SRC).unwrap();
        let buf = ctx.alloc_buffer::<f32>(64, 0).unwrap();
        ctx.upload(&buf, &[1.0; 64]).unwrap();
        let s = ctx.create_stream(0).unwrap();
        ctx.launch(m, "bump").dims(LaunchDims::d1(2, 32)).arg(buf.arg()).record(s).unwrap();
        let host = crate::runtime::memory::PinnedBuffer::new(64 * 4);
        let ev = ctx.memcpy_d2h_async(s, &host, buf.ptr()).unwrap();
        ctx.synchronize(s).unwrap();
        assert_eq!(ctx.event_query(ev).unwrap(), EventStatus::Completed);
        // The copy is stream-ordered after the launch, so it must observe
        // the bumped values.
        assert!(host.read::<f32>().iter().all(|v| *v == 2.0));
    }

    #[test]
    fn peer_copy_moves_bytes_between_device_arenas() {
        let ctx =
            HetGpu::with_devices(&[DeviceKind::NvidiaSim, DeviceKind::AmdSim]).unwrap();
        let m = ctx.compile_cuda(BUMP_SRC).unwrap();
        let buf = ctx.alloc_buffer::<f32>(64, 0).unwrap();
        ctx.upload(&buf, &[3.0; 64]).unwrap();
        // Stream on device 1 pulls the image from device 0, then bumps it
        // locally — the launch only sees correct input if the peer copy
        // is stream-ordered before it.
        let s = ctx.create_stream(1).unwrap();
        ctx.memcpy_peer_async(s, buf.ptr(), buf.size_bytes(), 0).unwrap();
        ctx.launch(m, "bump").dims(LaunchDims::d1(2, 32)).arg(buf.arg()).record(s).unwrap();
        let host = crate::runtime::memory::PinnedBuffer::new(64 * 4);
        ctx.memcpy_d2h_async(s, &host, buf.ptr()).unwrap();
        ctx.synchronize(s).unwrap();
        assert!(host.read::<f32>().iter().all(|v| *v == 4.0), "{:?}", host.read::<f32>());
    }
}
