//! Event-graph stream executor — the runtime's scheduling seam.
//!
//! The previous runtime gave every stream its own OS thread that executed
//! launches *blocking*, so the PR-1 dispatch pool sat idle between kernels
//! and two streams could only overlap by accident of having separate
//! threads. This module replaces that with the paper's §4.3 command-graph
//! model: a [`crate::runtime::stream::Stream`] is a thin handle that
//! *records* commands — launch, copy, cross-stream waits (markers), resume
//! — as nodes of a per-runtime DAG, and a small pool of executor threads
//! drains **ready** nodes onto the shared block-dispatch pool.
//!
//! Graph shape and the invariants it preserves:
//!
//! * **Per-stream FIFO.** Every node has an implicit dependency on its
//!   stream predecessor (streams are queues in the graph); a node is ready
//!   only when it is at the front of its stream. Cross-stream edges are
//!   explicit `deps` (recorded by wait-event-style marker nodes); a node
//!   additionally waits for those to reach a terminal state.
//! * **Halt semantics.** When a launch returns `Paused` (cooperative
//!   checkpoint), the stream *halts*: its queued nodes stay pending — the
//!   paper's "deferred until migration completes" — and only a `Resume`
//!   node (pushed to the queue front by [`EventGraph::resume`]) may run.
//!   Resume re-enters the kernel from its captured per-block state,
//!   possibly on a different device, then the deferred queue drains in the
//!   original FIFO order.
//! * **Sticky errors.** A failing node poisons its stream: nodes already
//!   queued behind it (and any recorded later) fail terminally — they can
//!   never execute, and leaving them queued would hang cross-stream
//!   waiters — while every `synchronize` keeps reporting the first error,
//!   like the old per-stream worker. Other streams are unaffected unless
//!   they wait on a failed event, which poisons them in turn.
//! * **Device overlap.** Executors run `RuntimeInner::run_launch`, which
//!   takes the device gate *shared* — independent launches overlap both
//!   across devices and on one device, sharing host cores through the
//!   dispatch-pool budget (`sim::dispatch::budget`).
//!
//! Sharded launches (the multi-device coordinator) enter here too: a launch
//! node may carry a [`ShardRange`], which the executor lowers to per-block
//! resume directives (`Skip` outside the range) — the same mechanism
//! migration resume uses, so a shard can itself pause and be rebalanced.

use crate::coordinator::shard::ShardRange;
use crate::error::{HetError, Result};
use crate::runtime::launch::LaunchSpec;
use crate::runtime::memory::GpuPtr;
use crate::runtime::stream::{PausedKernel, StreamStats};
use crate::runtime::RuntimeInner;
use crate::sim::snapshot::{BlockResume, CostReport, LaunchOutcome};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Handle to a recorded command node (CUDA-event-like).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EventId(pub u64);

/// Lifecycle of a graph node, observable via [`EventGraph::query`].
#[derive(Debug, Clone, PartialEq)]
pub enum EventStatus {
    /// Recorded, not yet picked by an executor (possibly deferred behind a
    /// halt or unsatisfied dependencies).
    Queued,
    Running,
    /// Executed. A launch that *paused* at a checkpoint is still
    /// `Completed` — the pause is stream state, not a node failure.
    Completed,
    Failed(String),
}

impl EventStatus {
    pub fn is_terminal(&self) -> bool {
        matches!(self, EventStatus::Completed | EventStatus::Failed(_))
    }
}

/// What a recorded command does when an executor picks it.
pub(crate) enum NodeKind {
    /// Kernel launch; `shard` restricts execution to a block range.
    Launch { spec: LaunchSpec, shard: Option<ShardRange> },
    /// Re-enter a paused kernel from its captured per-block state.
    Resume { paused: Box<PausedKernel> },
    /// Asynchronous host→device copy into unified memory.
    CopyH2D { dst: GpuPtr, data: Vec<u8> },
    /// No-op synchronization point (carries cross-stream `deps`).
    Marker,
}

struct Node {
    id: u64,
    kind: NodeKind,
    /// Explicit cross-stream dependencies (event ids); the implicit
    /// same-stream predecessor edge is the queue order itself.
    deps: Vec<u64>,
}

struct StreamState {
    device: usize,
    queue: VecDeque<Node>,
    /// An executor is currently running this stream's front node.
    running: bool,
    /// Halted at a checkpoint; queued nodes are deferred until `Resume`.
    halted: bool,
    sticky: Option<String>,
    paused: Option<PausedKernel>,
    stats: StreamStats,
}

struct GraphInner {
    streams: Vec<StreamState>,
    /// Status of every node ever recorded (event queries stay valid after
    /// completion; bounded by commands recorded in the context's lifetime).
    status: HashMap<u64, EventStatus>,
    shutdown: bool,
}

/// The per-runtime command DAG plus its executor pool's shared state.
pub struct EventGraph {
    rt: Arc<RuntimeInner>,
    inner: Mutex<GraphInner>,
    /// Single condvar for both edges: executors wait for ready nodes,
    /// `synchronize` waits for completions; every state change notifies all.
    cv: Condvar,
    next_id: AtomicU64,
}

impl EventGraph {
    pub fn new(rt: Arc<RuntimeInner>) -> Arc<EventGraph> {
        Arc::new(EventGraph {
            rt,
            inner: Mutex::new(GraphInner {
                streams: Vec::new(),
                status: HashMap::new(),
                shutdown: false,
            }),
            cv: Condvar::new(),
            next_id: AtomicU64::new(1),
        })
    }

    /// Start `n` executor threads draining `graph`.
    pub fn spawn_executors(graph: &Arc<EventGraph>, n: usize) -> Vec<JoinHandle<()>> {
        (0..n.max(1))
            .map(|i| {
                let g = Arc::clone(graph);
                std::thread::Builder::new()
                    .name(format!("hetgpu-exec-{i}"))
                    .spawn(move || executor_loop(&g))
                    .expect("spawn graph executor")
            })
            .collect()
    }

    /// Stop the executor pool (queued nodes are abandoned; contexts
    /// synchronize before dropping if they care).
    pub fn shutdown(&self) {
        self.inner.lock().unwrap().shutdown = true;
        self.cv.notify_all();
    }

    /// Register a new stream bound to `device`; returns its id.
    pub fn add_stream(&self, device: usize) -> usize {
        let mut g = self.inner.lock().unwrap();
        g.streams.push(StreamState {
            device,
            queue: VecDeque::new(),
            running: false,
            halted: false,
            sticky: None,
            paused: None,
            stats: StreamStats::default(),
        });
        g.streams.len() - 1
    }

    /// Record a command node at the back of `stream`'s queue.
    pub(crate) fn enqueue(
        &self,
        stream: usize,
        kind: NodeKind,
        deps: &[EventId],
    ) -> Result<EventId> {
        let mut g = self.inner.lock().unwrap();
        if g.shutdown {
            return Err(HetError::runtime("runtime is shutting down"));
        }
        let st =
            g.streams.get(stream).ok_or_else(|| HetError::runtime("bad stream handle"))?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        if st.sticky.is_some() {
            // A poisoned stream never runs another node; record the event
            // as terminally failed (rather than queued-forever) so
            // cross-stream waiters observe a terminal state. The sticky
            // error still surfaces at this stream's synchronize.
            g.status.insert(id, EventStatus::Failed("stream poisoned by earlier error".into()));
        } else {
            g.status.insert(id, EventStatus::Queued);
            g.streams[stream]
                .queue
                .push_back(Node { id, kind, deps: deps.iter().map(|e| e.0).collect() });
        }
        drop(g);
        self.cv.notify_all();
        Ok(EventId(id))
    }

    /// Status of a recorded event.
    pub fn query(&self, ev: EventId) -> Result<EventStatus> {
        self.inner
            .lock()
            .unwrap()
            .status
            .get(&ev.0)
            .cloned()
            .ok_or_else(|| HetError::runtime(format!("unknown event {}", ev.0)))
    }

    pub fn stream_device(&self, stream: usize) -> Result<usize> {
        let g = self.inner.lock().unwrap();
        g.streams
            .get(stream)
            .map(|s| s.device)
            .ok_or_else(|| HetError::runtime("bad stream handle"))
    }

    pub fn stats(&self, stream: usize) -> Result<StreamStats> {
        let g = self.inner.lock().unwrap();
        g.streams
            .get(stream)
            .map(|s| s.stats.clone())
            .ok_or_else(|| HetError::runtime("bad stream handle"))
    }

    /// Wait until the stream can make no further progress: its queue is
    /// drained, or blocked by a halt / sticky error. Reports the sticky
    /// error if any; leaves deferred nodes queued (they run after resume).
    pub fn synchronize(&self, stream: usize) -> Result<()> {
        self.wait_idle(stream).map(|_halted| ())
    }

    /// Like [`EventGraph::synchronize`], additionally reporting whether the
    /// stream is halted at a checkpoint (the migration orchestrator asks).
    pub fn quiesce(&self, stream: usize) -> Result<bool> {
        self.wait_idle(stream)
    }

    fn wait_idle(&self, stream: usize) -> Result<bool> {
        let mut g = self.inner.lock().unwrap();
        loop {
            let st = g
                .streams
                .get(stream)
                .ok_or_else(|| HetError::runtime("bad stream handle"))?;
            // A halted stream still makes progress through a front `Resume`
            // node (the re-entry the orchestrator just recorded), so only a
            // halt with ordinary deferred work counts as blocked.
            let front_resume = st
                .queue
                .front()
                .map(|n| matches!(n.kind, NodeKind::Resume { .. }))
                .unwrap_or(false);
            let blocked = st.sticky.is_some() || (st.halted && !front_resume);
            if !st.running && (st.queue.is_empty() || blocked) {
                return match &st.sticky {
                    Some(e) => Err(HetError::runtime(format!("stream {stream}: {e}"))),
                    None => Ok(st.halted),
                };
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Take the paused kernel (leaves the stream halted until resume).
    pub fn take_paused(&self, stream: usize) -> Result<Option<PausedKernel>> {
        let mut g = self.inner.lock().unwrap();
        g.streams
            .get_mut(stream)
            .map(|s| s.paused.take())
            .ok_or_else(|| HetError::runtime("bad stream handle"))
    }

    /// Rebind the stream to `device` and re-enter the restored kernel (or
    /// just un-halt when `paused` is `None`). The target device is
    /// validated *before* anything is acknowledged — an invalid id errors
    /// here, at the resume call, never as a later sticky stream error. The
    /// re-entry itself runs asynchronously on the executor pool (the
    /// chained H100→AMD→Tenstorrent scenario of §6.3 triggers the next
    /// checkpoint while it runs); its failures become sticky errors.
    pub fn resume(
        &self,
        stream: usize,
        device: usize,
        paused: Option<PausedKernel>,
    ) -> Result<()> {
        self.rt.device(device)?; // validate before acking
        {
            let mut guard = self.inner.lock().unwrap();
            let inner = &mut *guard;
            let st = inner
                .streams
                .get_mut(stream)
                .ok_or_else(|| HetError::runtime("bad stream handle"))?;
            st.device = device;
            match paused {
                Some(pk) => {
                    // Jump the deferred queue: re-entry precedes every
                    // command deferred while the stream was halted.
                    let id = self.next_id.fetch_add(1, Ordering::Relaxed);
                    st.queue.push_front(Node {
                        id,
                        kind: NodeKind::Resume { paused: Box::new(pk) },
                        deps: Vec::new(),
                    });
                    inner.status.insert(id, EventStatus::Queued);
                }
                None => st.halted = false,
            }
        }
        self.cv.notify_all();
        Ok(())
    }

    /// Resume-in-place every stream on `device` (except `exclude`) that
    /// was collaterally halted by the device-wide pause flag. The
    /// checkpoint protocol pauses a whole device, and with launches
    /// overlapping on one device an unrelated stream's kernel can observe
    /// the flag and halt too; nothing would ever resume it, and its
    /// deferred work would silently never run. Callers invoke this after
    /// the capture window (the exclusive device gate has been released, so
    /// every launch that observed the flag has already halted); captured
    /// kernels re-enter on their own device and deferred queues drain.
    pub fn resume_collateral(&self, device: usize, exclude: usize) {
        {
            let mut guard = self.inner.lock().unwrap();
            // A stream whose launch just returned Paused may not have had
            // its halt folded into the graph yet (the executor publishes
            // after releasing the device gate) — wait for every running
            // node on this device to settle so no collateral halt is
            // missed.
            loop {
                let busy = guard
                    .streams
                    .iter()
                    .enumerate()
                    .any(|(si, st)| si != exclude && st.device == device && st.running);
                if !busy || guard.shutdown {
                    break;
                }
                guard = self.cv.wait(guard).unwrap();
            }
            let inner = &mut *guard;
            for (si, st) in inner.streams.iter_mut().enumerate() {
                if si == exclude || st.device != device || !st.halted {
                    continue;
                }
                match st.paused.take() {
                    Some(pk) => {
                        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
                        st.queue.push_front(Node {
                            id,
                            kind: NodeKind::Resume { paused: Box::new(pk) },
                            deps: Vec::new(),
                        });
                        inner.status.insert(id, EventStatus::Queued);
                    }
                    // Halted with its capture already harvested elsewhere:
                    // nothing to re-enter, just unblock the queue.
                    None => st.halted = false,
                }
            }
        }
        self.cv.notify_all();
    }
}

/// Outcome of executing one node, before it is folded back into the graph.
enum Exec {
    Launch {
        cost: CostReport,
        wall_us: f64,
        workers: usize,
        completed: bool,
        paused: Option<PausedKernel>,
    },
    Plain,
}

/// Pick a ready node: front of a non-running, non-poisoned stream, with
/// all explicit deps terminal; a halted stream only offers `Resume`. The
/// returned flag is true when a dependency *failed* — the caller must
/// fail the node without executing it (a cross-stream edge from a failed
/// producer must poison the consumer, not silently satisfy it).
fn take_ready(g: &mut GraphInner) -> Option<(usize, usize, Node, bool)> {
    for si in 0..g.streams.len() {
        let st = &g.streams[si];
        if st.running || st.sticky.is_some() || st.queue.is_empty() {
            continue;
        }
        let front = st.queue.front().unwrap();
        if st.halted && !matches!(front.kind, NodeKind::Resume { .. }) {
            continue;
        }
        let mut dep_failed = false;
        let mut deps_terminal = true;
        for d in &front.deps {
            // A dep missing from the status map cannot happen via the
            // public API (ids are handed out by enqueue); treat it as
            // satisfied.
            match g.status.get(d) {
                Some(EventStatus::Failed(_)) => dep_failed = true,
                Some(s) if !s.is_terminal() => deps_terminal = false,
                _ => {}
            }
        }
        if !deps_terminal {
            continue;
        }
        let st = &mut g.streams[si];
        let device = st.device;
        let node = st.queue.pop_front().unwrap();
        st.running = true;
        g.status.insert(node.id, EventStatus::Running);
        return Some((si, device, node, dep_failed));
    }
    None
}

fn executor_loop(g: &EventGraph) {
    loop {
        let (si, device, node, dep_failed) = {
            let mut inner = g.inner.lock().unwrap();
            loop {
                if inner.shutdown {
                    return;
                }
                if let Some(t) = take_ready(&mut inner) {
                    break t;
                }
                inner = g.cv.wait(inner).unwrap();
            }
        };

        let result = if dep_failed {
            Err(HetError::runtime("awaited event failed"))
        } else {
            execute_node(&g.rt, device, &node.kind)
        };

        {
            let mut guard = g.inner.lock().unwrap();
            // Split the guard once so stream and status borrows are
            // disjoint field projections.
            let inner = &mut *guard;
            let st = &mut inner.streams[si];
            st.running = false;
            match result {
                Ok(Exec::Launch { cost, wall_us, workers, completed, paused }) => {
                    st.stats.record_launch(device, workers, wall_us, &cost, completed);
                    if let Some(pk) = paused {
                        st.paused = Some(pk);
                        st.halted = true;
                    } else if matches!(node.kind, NodeKind::Resume { .. }) {
                        st.halted = false;
                    }
                    inner.status.insert(node.id, EventStatus::Completed);
                }
                Ok(Exec::Plain) => {
                    inner.status.insert(node.id, EventStatus::Completed);
                }
                Err(e) => {
                    let msg = e.to_string();
                    st.sticky.get_or_insert(msg.clone());
                    // Everything deferred behind the poison will never
                    // run; fail those nodes now so cross-stream waiters
                    // (wait_event deps) reach a terminal state instead of
                    // hanging on events that can no longer happen.
                    let stranded: Vec<u64> = st.queue.iter().map(|n| n.id).collect();
                    st.queue.clear();
                    inner.status.insert(node.id, EventStatus::Failed(msg));
                    for id in stranded {
                        inner.status.insert(
                            id,
                            EventStatus::Failed("stream poisoned by earlier error".into()),
                        );
                    }
                }
            }
        }
        g.cv.notify_all();
    }
}

/// Lower a shard range to per-block resume directives: blocks outside the
/// range are `Skip`ped (committed as `Done` without running).
pub(crate) fn shard_directives(grid_size: u32, range: ShardRange) -> Vec<BlockResume> {
    (0..grid_size)
        .map(|b| if range.contains(b) { BlockResume::FromEntry } else { BlockResume::Skip })
        .collect()
}

fn execute_node(rt: &RuntimeInner, device: usize, kind: &NodeKind) -> Result<Exec> {
    match kind {
        NodeKind::Launch { spec, shard } => {
            let dirs = match shard {
                Some(r) => {
                    let (grid_size, _) = spec.dims.validate()?;
                    if r.is_empty() || r.hi > grid_size {
                        return Err(HetError::runtime(format!(
                            "shard range {}..{} outside grid of {grid_size} blocks",
                            r.lo, r.hi
                        )));
                    }
                    Some(shard_directives(grid_size, *r))
                }
                None => None,
            };
            run_timed(rt, device, spec, dirs.as_deref())
        }
        NodeKind::Resume { paused } => {
            let dirs = paused.resume_directives();
            run_timed(rt, device, &paused.spec, Some(&dirs))
        }
        NodeKind::CopyH2D { dst, data } => {
            let (base, size, dev_id) = rt.memory.lookup(*dst)?;
            if dst.0 + data.len() as u64 > base + size {
                return Err(HetError::runtime("h2d copy out of bounds"));
            }
            let dev = rt.device(dev_id)?;
            let _gate = dev.exec.read().unwrap();
            dev.mem.write_bytes(dst.0, data)?;
            Ok(Exec::Plain)
        }
        NodeKind::Marker => Ok(Exec::Plain),
    }
}

fn run_timed(
    rt: &RuntimeInner,
    device: usize,
    spec: &LaunchSpec,
    resume: Option<&[BlockResume]>,
) -> Result<Exec> {
    let t0 = Instant::now();
    let outcome = rt.run_launch(device, spec, resume)?;
    let wall_us = t0.elapsed().as_secs_f64() * 1e6;
    let workers = rt.device(device).map(|d| d.engine.workers()).unwrap_or(1);
    let cost = *outcome.cost();
    // Move the captured block states out (they can be every thread's
    // registers plus shared memory — cloning them would sit directly in
    // the checkpoint latency path).
    let (completed, paused) = match outcome {
        LaunchOutcome::Completed(_) => (true, None),
        LaunchOutcome::Paused { grid, .. } => {
            (false, Some(PausedKernel { spec: spec.clone(), blocks: grid.blocks }))
        }
    };
    Ok(Exec::Launch { cost, wall_us, workers, completed, paused })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::api::HetGpu;
    use crate::runtime::device::DeviceKind;
    use crate::runtime::launch::Arg;
    use crate::sim::simt::LaunchDims;

    const BUMP_SRC: &str = r#"
__global__ void bump(float* p) {
    unsigned i = blockIdx.x * blockDim.x + threadIdx.x;
    p[i] = p[i] + 1.0f;
}
"#;

    #[test]
    fn event_lifecycle_and_query() {
        let ctx = HetGpu::with_devices(&[DeviceKind::NvidiaSim]).unwrap();
        let m = ctx.compile_cuda(BUMP_SRC).unwrap();
        let buf = ctx.malloc_on(256, 0).unwrap();
        ctx.upload_f32(buf, &[0.0; 64]).unwrap();
        let s = ctx.create_stream(0).unwrap();
        let ev = ctx.launch(s, m, "bump", LaunchDims::d1(2, 32), &[Arg::Ptr(buf)]).unwrap();
        ctx.synchronize(s).unwrap();
        assert_eq!(ctx.event_query(ev).unwrap(), EventStatus::Completed);
        assert!(ctx.event_query(EventId(u64::MAX)).is_err());
    }

    #[test]
    fn sticky_error_defers_later_work_and_reports_at_sync() {
        let ctx = HetGpu::with_devices(&[DeviceKind::NvidiaSim]).unwrap();
        let m = ctx.compile_cuda(BUMP_SRC).unwrap();
        let buf = ctx.malloc_on(256, 0).unwrap();
        let s = ctx.create_stream(0).unwrap();
        // Wrong arg count fails inside the executor -> sticky.
        let bad = ctx.launch(s, m, "bump", LaunchDims::d1(2, 32), &[]).unwrap();
        let after = ctx.launch(s, m, "bump", LaunchDims::d1(2, 32), &[Arg::Ptr(buf)]).unwrap();
        assert!(ctx.synchronize(s).is_err());
        assert!(matches!(ctx.event_query(bad).unwrap(), EventStatus::Failed(_)));
        // The launch deferred behind the failure never ran — it fails
        // terminally (so nothing can hang waiting on it) instead of
        // staying queued forever.
        assert!(matches!(ctx.event_query(after).unwrap(), EventStatus::Failed(_)));
        // Sticky errors stay sticky, including for newly recorded work.
        assert!(ctx.synchronize(s).is_err());
        let late = ctx.launch(s, m, "bump", LaunchDims::d1(2, 32), &[Arg::Ptr(buf)]).unwrap();
        assert!(matches!(ctx.event_query(late).unwrap(), EventStatus::Failed(_)));
        assert!(ctx.synchronize(s).is_err());
    }

    #[test]
    fn resume_rejects_invalid_device_before_ack() {
        let ctx = HetGpu::with_devices(&[DeviceKind::NvidiaSim]).unwrap();
        let s = ctx.create_stream(0).unwrap();
        // Surfaces immediately, not as a later sticky stream error.
        let err = ctx.graph().resume(s.0, 7, None).unwrap_err();
        assert!(err.to_string().contains("no device 7"), "{err}");
        ctx.synchronize(s).unwrap();
    }

    #[test]
    fn cross_stream_marker_orders_work() {
        let ctx = HetGpu::with_devices(&[DeviceKind::NvidiaSim]).unwrap();
        let m = ctx
            .compile_cuda(
                r#"
__global__ void produce(unsigned* p, unsigned iters) {
    unsigned acc = 0u;
    for (unsigned k = 0u; k < iters; k++) { acc = acc + 1u; }
    if (threadIdx.x == 0u && blockIdx.x == 0u) p[1] = acc;
}
__global__ void consume(unsigned* p) {
    if (threadIdx.x == 0u && blockIdx.x == 0u) p[2] = p[1] * 10u;
}
"#,
            )
            .unwrap();
        // Stream b waits on a's (slow) producer event, so the consumer must
        // observe p[1] — without the edge it would read 0.
        let buf = ctx.malloc_on(256, 0).unwrap();
        ctx.upload_u32(buf, &[0; 16]).unwrap();
        let a = ctx.create_stream(0).unwrap();
        let b = ctx.create_stream(0).unwrap();
        let ev = ctx
            .launch(a, m, "produce", LaunchDims::d1(1, 32), &[Arg::Ptr(buf), Arg::U32(50_000)])
            .unwrap();
        ctx.wait_event(b, ev).unwrap();
        ctx.launch(b, m, "consume", LaunchDims::d1(1, 32), &[Arg::Ptr(buf)]).unwrap();
        ctx.synchronize(b).unwrap();
        ctx.synchronize(a).unwrap();
        let got = ctx.download_u32(buf, 3).unwrap();
        assert_eq!(got[1], 50_000);
        assert_eq!(got[2], 500_000, "consumer ran before the awaited producer");
    }

    #[test]
    fn failed_dependency_poisons_waiting_stream() {
        let ctx = HetGpu::with_devices(&[DeviceKind::NvidiaSim]).unwrap();
        let m = ctx.compile_cuda(BUMP_SRC).unwrap();
        let buf = ctx.malloc_on(256, 0).unwrap();
        let a = ctx.create_stream(0).unwrap();
        let b = ctx.create_stream(0).unwrap();
        // Wrong arg count: the producer launch fails in the executor.
        let bad = ctx.launch(a, m, "bump", LaunchDims::d1(2, 32), &[]).unwrap();
        ctx.wait_event(b, bad).unwrap();
        let after = ctx.launch(b, m, "bump", LaunchDims::d1(2, 32), &[Arg::Ptr(buf)]).unwrap();
        // The cross-stream edge must carry the failure, not satisfy it.
        assert!(ctx.synchronize(b).is_err());
        assert!(matches!(ctx.event_query(after).unwrap(), EventStatus::Failed(_)));
        assert!(ctx.synchronize(a).is_err());
    }

    #[test]
    fn async_h2d_copy_is_fifo_with_launches() {
        let ctx = HetGpu::with_devices(&[DeviceKind::NvidiaSim]).unwrap();
        let m = ctx.compile_cuda(BUMP_SRC).unwrap();
        let buf = ctx.malloc_on(256, 0).unwrap();
        let s = ctx.create_stream(0).unwrap();
        let init: Vec<u8> = [5.0f32; 64].iter().flat_map(|v| v.to_le_bytes()).collect();
        ctx.memcpy_h2d_async(s, buf, &init).unwrap();
        ctx.launch(s, m, "bump", LaunchDims::d1(2, 32), &[Arg::Ptr(buf)]).unwrap();
        ctx.synchronize(s).unwrap();
        assert!(ctx.download_f32(buf, 64).unwrap().iter().all(|v| *v == 6.0));
    }
}
