//! Device registry: the simulated GPUs the runtime can schedule onto.
//!
//! Mirrors paper §5.2 "the runtime detects devices via environment
//! variables or a config file" — here, devices are declared when the
//! [`crate::runtime::api::HetGpu`] context is created.

use crate::isa::simt_isa::SimtConfig;
use crate::isa::tensix_isa::TensixConfig;
use crate::sim::dispatch::DispatchOptions;
use crate::sim::mem::DeviceMemory;
use crate::sim::simt::SimtSim;
use crate::sim::tensix::TensixSim;
use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::RwLock;

/// Operational health of a device (fault-tolerance layer).
///
/// `Healthy → Degraded` on a recovered fault (retried copy or shard);
/// `* → Quarantined` on an unrecovered fault or a fail-fast policy;
/// `Quarantined → Healthy` only through a successful
/// `HetGpu::probe_device`. Quarantine gates *execution placement*
/// (stream creation, shard planning) — memory on the device stays
/// readable so snapshots and evacuation keep working.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthState {
    Healthy,
    Degraded,
    Quarantined,
}

impl HealthState {
    fn from_u8(v: u8) -> HealthState {
        match v {
            1 => HealthState::Degraded,
            2 => HealthState::Quarantined,
            _ => HealthState::Healthy,
        }
    }

    fn as_u8(self) -> u8 {
        match self {
            HealthState::Healthy => 0,
            HealthState::Degraded => 1,
            HealthState::Quarantined => 2,
        }
    }
}

/// The GPU vendors hetGPU supports (paper abstract: NVIDIA, AMD, Intel,
/// Tenstorrent). `AmdWave64Sim` is the GCN-era wave64 configuration used
/// by the divergence ablation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceKind {
    NvidiaSim,
    AmdSim,
    AmdWave64Sim,
    IntelSim,
    TenstorrentSim,
}

impl DeviceKind {
    pub fn name(self) -> &'static str {
        match self {
            DeviceKind::NvidiaSim => "nvidia-sim",
            DeviceKind::AmdSim => "amd-sim",
            DeviceKind::AmdWave64Sim => "amd-sim-w64",
            DeviceKind::IntelSim => "intel-sim",
            DeviceKind::TenstorrentSim => "tenstorrent-sim",
        }
    }

    /// All kinds (the paper's four-vendor testbed plus the wave64 ablation).
    pub fn all() -> [DeviceKind; 4] {
        [DeviceKind::NvidiaSim, DeviceKind::AmdSim, DeviceKind::IntelSim, DeviceKind::TenstorrentSim]
    }

    pub fn is_simt(self) -> bool {
        !matches!(self, DeviceKind::TenstorrentSim)
    }

    /// Parse from a CLI/name string.
    pub fn parse(s: &str) -> Option<DeviceKind> {
        Some(match s {
            "nvidia" | "nvidia-sim" => DeviceKind::NvidiaSim,
            "amd" | "amd-sim" => DeviceKind::AmdSim,
            "amd-w64" | "amd-sim-w64" => DeviceKind::AmdWave64Sim,
            "intel" | "intel-sim" => DeviceKind::IntelSim,
            "tenstorrent" | "tenstorrent-sim" | "tt" => DeviceKind::TenstorrentSim,
            _ => return None,
        })
    }
}

/// The execution engine behind a device.
pub enum Engine {
    Simt(SimtSim),
    Tensix(TensixSim),
}

impl Engine {
    pub fn clock_mhz(&self) -> u64 {
        match self {
            Engine::Simt(s) => s.cfg.clock_mhz,
            Engine::Tensix(t) => t.cfg.clock_mhz,
        }
    }

    /// Dispatch worker threads this engine spreads thread blocks over.
    pub fn workers(&self) -> usize {
        match self {
            Engine::Simt(s) => s.dispatch.workers,
            Engine::Tensix(t) => t.dispatch.workers,
        }
    }

    fn set_dispatch(&mut self, opts: DispatchOptions) {
        match self {
            Engine::Simt(s) => s.dispatch = opts,
            Engine::Tensix(t) => t.dispatch = opts,
        }
    }
}

/// One simulated GPU: engine + DRAM + the cooperative pause flag.
pub struct Device {
    pub id: usize,
    pub kind: DeviceKind,
    pub engine: Engine,
    /// Device DRAM. Interior-mutable (word-atomic arena), so launches and
    /// host copies target it concurrently — the event-graph executor
    /// overlaps independent launches on the same device. Multi-byte
    /// concurrent accesses to the *same* region are the application's race,
    /// exactly as on real hardware.
    pub mem: DeviceMemory,
    /// Execution gate: launches and streamed copies take it shared;
    /// whole-device snapshot capture/restore takes it exclusive so a
    /// checkpoint never reads a half-running kernel's memory image.
    pub exec: RwLock<()>,
    /// Cooperative pause flag (paper §4.2): checked by compiled-in
    /// checkpoint guards and at block-dispatch boundaries.
    pub pause: AtomicBool,
    /// Operational health (see [`HealthState`]); written by the fault
    /// plane, read at stream creation and shard planning.
    health: AtomicU8,
}

/// Default simulated DRAM size per device (256 MiB — enough for every
/// workload in the evaluation while keeping allocation cheap).
pub const DEVICE_MEM_BYTES: u64 = 256 << 20;

impl Device {
    pub fn new(id: usize, kind: DeviceKind) -> Device {
        let engine = match kind {
            DeviceKind::NvidiaSim => Engine::Simt(SimtSim::new(SimtConfig::nvidia())),
            DeviceKind::AmdSim => Engine::Simt(SimtSim::new(SimtConfig::amd())),
            DeviceKind::AmdWave64Sim => Engine::Simt(SimtSim::new(SimtConfig::amd_wave64())),
            DeviceKind::IntelSim => Engine::Simt(SimtSim::new(SimtConfig::intel())),
            DeviceKind::TenstorrentSim => Engine::Tensix(TensixSim::new(TensixConfig::blackhole())),
        };
        Device {
            id,
            kind,
            engine,
            mem: DeviceMemory::new(DEVICE_MEM_BYTES, kind.name()),
            exec: RwLock::new(()),
            pause: AtomicBool::new(false),
            health: AtomicU8::new(HealthState::Healthy.as_u8()),
        }
    }

    /// Current operational health.
    pub fn health(&self) -> HealthState {
        HealthState::from_u8(self.health.load(Ordering::Acquire))
    }

    /// Set operational health (fault plane / probe reinstatement).
    pub fn set_health(&self, state: HealthState) {
        self.health.store(state.as_u8(), Ordering::Release);
    }

    /// Like [`Device::new`] with an explicit dispatch worker count
    /// (overriding `HETGPU_SIM_THREADS`); `workers = 1` is the sequential
    /// escape hatch.
    pub fn new_with_workers(id: usize, kind: DeviceKind, workers: usize) -> Device {
        let mut d = Device::new(id, kind);
        d.engine.set_dispatch(DispatchOptions::with_workers(workers));
        d
    }

    /// Replace the Tensix engine configuration (perf-pass ablations).
    pub fn with_tensix_config(id: usize, cfg: TensixConfig) -> Device {
        Device {
            id,
            kind: DeviceKind::TenstorrentSim,
            engine: Engine::Tensix(TensixSim::new(cfg)),
            mem: DeviceMemory::new(DEVICE_MEM_BYTES, "tenstorrent-sim"),
            exec: RwLock::new(()),
            pause: AtomicBool::new(false),
            health: AtomicU8::new(HealthState::Healthy.as_u8()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kinds_parse_roundtrip() {
        for k in DeviceKind::all() {
            assert_eq!(DeviceKind::parse(k.name()), Some(k));
        }
        assert_eq!(DeviceKind::parse("tt"), Some(DeviceKind::TenstorrentSim));
        assert_eq!(DeviceKind::parse("riscv"), None);
    }

    #[test]
    fn device_construction() {
        let d = Device::new(0, DeviceKind::NvidiaSim);
        assert_eq!(d.kind.name(), "nvidia-sim");
        assert_eq!(d.mem.capacity(), DEVICE_MEM_BYTES);
        assert!(d.kind.is_simt());
        let t = Device::new(1, DeviceKind::TenstorrentSim);
        assert!(!t.kind.is_simt());
    }

    #[test]
    fn health_transitions() {
        let d = Device::new(0, DeviceKind::NvidiaSim);
        assert_eq!(d.health(), HealthState::Healthy);
        d.set_health(HealthState::Degraded);
        assert_eq!(d.health(), HealthState::Degraded);
        d.set_health(HealthState::Quarantined);
        assert_eq!(d.health(), HealthState::Quarantined);
        d.set_health(HealthState::Healthy);
        assert_eq!(d.health(), HealthState::Healthy);
    }
}
