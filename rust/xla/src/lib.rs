//! Build-hermetic stub of the `xla` PJRT bindings.
//!
//! The real crate links a prebuilt XLA/PJRT shared library that is not
//! available in every build environment. This stub exposes the exact API
//! surface `hetgpu::xla_native` consumes so the crate always compiles:
//! client construction succeeds (letting callers probe for compiled HLO
//! artifacts and skip gracefully), while anything that would actually
//! compile or execute an HLO module returns [`Error`]. Swap the `xla`
//! path dependency for the real bindings to light up the vendor-native
//! benchmark columns.

use std::fmt;

/// Error type mirroring the real bindings' catch-all error.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!("{what}: PJRT runtime unavailable (hetgpu built against the xla stub)")))
}

/// PJRT client handle. `cpu()` succeeds so the caller can construct its
/// artifact cache and decide per-artifact whether to skip.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compile")
    }
}

/// Parsed HLO module proto (text form).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Host-side literal value.
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        unavailable("Literal::array_shape")
    }

    pub fn to_vec<T: Clone>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Array shape of a literal.
pub struct ArrayShape;

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &[]
    }
}

/// Device-side buffer returned by an execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A compiled, loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructs_but_execution_is_unavailable() {
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation::from_proto(&HloModuleProto);
        assert!(client.compile(&comp).is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent.hlo.txt").is_err());
        let e = Literal::vec1(&[1.0]).reshape(&[1]).unwrap_err();
        assert!(e.to_string().contains("stub"));
    }
}
