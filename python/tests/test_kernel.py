"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps the kernel's shape space; assert_allclose against ref —
this is the CORE correctness signal gating the AOT artifacts (the paper's
§5.3 microbenchmark validation, here automated).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.matmul_tiled import matmul_tiled, vecadd


def rng(seed):
    return np.random.default_rng(seed)


# ---- Pallas tiled matmul vs ref ----

@settings(max_examples=12, deadline=None)
@given(
    mi=st.integers(min_value=1, max_value=3),
    ni=st.integers(min_value=1, max_value=3),
    k=st.sampled_from([32, 64, 128, 256]),
    tile=st.sampled_from([32, 64]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matmul_tiled_matches_ref(mi, ni, k, tile, seed):
    m, n = mi * tile, ni * tile
    r = rng(seed)
    a = jnp.asarray(r.standard_normal((m, k), dtype=np.float32))
    b = jnp.asarray(r.standard_normal((k, n), dtype=np.float32))
    got = matmul_tiled(a, b, tile=tile)
    want = ref.matmul(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_matmul_tiled_rejects_unaligned():
    a = jnp.zeros((100, 64), jnp.float32)
    b = jnp.zeros((64, 128), jnp.float32)
    with pytest.raises(AssertionError):
        matmul_tiled(a, b, tile=64)


def test_matmul_512_default_tile():
    r = rng(7)
    a = jnp.asarray(r.standard_normal((512, 512), dtype=np.float32))
    b = jnp.asarray(r.standard_normal((512, 512), dtype=np.float32))
    np.testing.assert_allclose(
        matmul_tiled(a, b), ref.matmul(a, b), rtol=1e-3, atol=1e-3
    )


# ---- Pallas vecadd vs ref ----

@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=4096),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_vecadd_matches_ref(n, seed):
    r = rng(seed)
    a = jnp.asarray(r.standard_normal(n, dtype=np.float32))
    b = jnp.asarray(r.standard_normal(n, dtype=np.float32))
    np.testing.assert_allclose(vecadd(a, b), ref.vecadd(a, b), rtol=1e-6)


# ---- L2 model shapes & training behaviour ----

def test_nn_layer_shape_and_relu():
    from compile import model

    r = rng(3)
    x = jnp.asarray(r.standard_normal((model.LAYER_B, model.LAYER_D), dtype=np.float32))
    w = jnp.asarray(r.standard_normal((model.LAYER_D, model.LAYER_H), dtype=np.float32))
    b = jnp.asarray(r.standard_normal(model.LAYER_H, dtype=np.float32))
    out = model.nn_layer(x, w, b)
    assert out.shape == (model.LAYER_B, model.LAYER_H)
    assert (np.asarray(out) >= 0).all(), "ReLU output must be non-negative"
    np.testing.assert_allclose(out, ref.nn_layer(x, w, b), rtol=1e-4, atol=1e-4)


def test_mlp_train_step_decreases_loss():
    from compile import model

    r = rng(11)
    w1 = jnp.asarray(0.05 * r.standard_normal((model.MLP_D, model.MLP_H), dtype=np.float32))
    b1 = jnp.zeros(model.MLP_H, jnp.float32)
    w2 = jnp.asarray(0.05 * r.standard_normal(model.MLP_H, dtype=np.float32))
    b2 = jnp.float32(0.0)
    x = jnp.asarray(r.standard_normal((model.MLP_B, model.MLP_D), dtype=np.float32))
    y = jnp.asarray(np.sin(np.asarray(x)[:, 0]).astype(np.float32))
    step = jax.jit(model.mlp_train_step)
    losses = []
    for _ in range(20):
        w1, b1, w2, b2, loss = step(w1, b1, w2, b2, x, y, jnp.float32(0.05))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, f"no learning: {losses[:3]} .. {losses[-3:]}"


def test_grad_flows_through_pallas_kernel():
    """jax.grad must differentiate through the interpret-mode Pallas call
    (the backward pass of the train step depends on this)."""
    from compile import model

    r = rng(5)
    w1 = jnp.asarray(0.1 * r.standard_normal((model.MLP_D, model.MLP_H), dtype=np.float32))
    b1 = jnp.zeros(model.MLP_H, jnp.float32)
    w2 = jnp.asarray(0.1 * r.standard_normal(model.MLP_H, dtype=np.float32))
    b2 = jnp.float32(0.0)
    x = jnp.asarray(r.standard_normal((model.MLP_B, model.MLP_D), dtype=np.float32))
    y = jnp.zeros(model.MLP_B, jnp.float32)
    g = jax.grad(model.mlp_loss)(w1, b1, w2, b2, x, y)
    assert g.shape == w1.shape
    assert float(jnp.abs(g).max()) > 0.0, "gradient through pallas_call is zero"
