"""L2: the JAX compute graphs AOT-lowered into artifacts.

These are the paper's evaluation workloads expressed at the framework
level, calling the L1 Pallas kernels where the shapes are tile-aligned:

* ``nn_layer`` — §6.1's "small neural-network layer (matrix-vector plus
  ReLU)", batched to (128, 256) @ (256, 128) so the Pallas tiled matmul
  carries the contraction.
* ``mlp_train_step`` — the §6.3 "CNN training iteration" stand-in: one
  fwd/bwd/SGD step of a two-layer MLP. jax.grad differentiates *through*
  the Pallas kernel (interpret mode is differentiable), so the backward
  pass exercises the same tiled matmul.

Build-time only: this module is lowered once by ``aot.py``; the Rust
runtime executes the resulting HLO via PJRT.
"""

import jax
import jax.numpy as jnp

from .kernels.matmul_tiled import matmul_tiled

# Fixed AOT shapes (HLO artifacts are shape-specialized).
LAYER_B, LAYER_D, LAYER_H = 128, 256, 128
MLP_B, MLP_D, MLP_H = 128, 128, 128


def nn_layer(x, w, b):
    """(B, D) @ (D, H) + b, ReLU — contraction via the Pallas kernel."""
    return jnp.maximum(matmul_tiled(x, w) + b, 0.0)


def mlp_forward(w1, b1, w2, b2, x):
    h = jnp.maximum(matmul_tiled(x, w1) + b1, 0.0)
    return h @ w2 + b2


def mlp_loss(w1, b1, w2, b2, x, y):
    pred = mlp_forward(w1, b1, w2, b2, x)
    return jnp.mean((pred - y) ** 2)


def mlp_train_step(w1, b1, w2, b2, x, y, lr):
    """One SGD step; returns (w1', b1', w2', b2', loss)."""
    loss, grads = jax.value_and_grad(mlp_loss, argnums=(0, 1, 2, 3))(
        w1, b1, w2, b2, x, y
    )
    g1, gb1, g2, gb2 = grads
    return (
        w1 - lr * g1,
        b1 - lr * gb1,
        w2 - lr * g2,
        b2 - lr * gb2,
        loss,
    )
