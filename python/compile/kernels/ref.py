"""Pure-jnp reference oracles (L1 correctness signal).

Every Pallas kernel in this package is checked against these functions by
``python/tests/test_kernel.py`` (pytest + hypothesis) before it is allowed
into an AOT artifact.
"""

import jax.numpy as jnp


def vecadd(a, b):
    return a + b


def saxpy(a, x, y):
    return a * x + y


def matmul(a, b):
    return jnp.matmul(a, b)


def reduction(x):
    return jnp.sum(x)


def nn_layer(x, w, b):
    """Matmul + bias + ReLU (the paper's §6.1 'small neural-network layer
    (matrix-vector plus ReLU)', batched)."""
    return jnp.maximum(x @ w + b, 0.0)


def mlp_forward(w1, b1, w2, b2, x):
    """Two-layer MLP regression head."""
    h = jnp.maximum(x @ w1 + b1, 0.0)
    return h @ w2 + b2


def mlp_loss(w1, b1, w2, b2, x, y):
    pred = mlp_forward(w1, b1, w2, b2, x)
    return jnp.mean((pred - y) ** 2)
