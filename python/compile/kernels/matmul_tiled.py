"""L1: tiled matrix-multiply as a Pallas kernel.

The paper's compute hot-spot is the 16x16 shared-memory-tiled matmul
(§6.1/§6.2). §Hardware-Adaptation (DESIGN.md): on the TPU-ish model the
CUDA shared-memory tiling becomes a Pallas ``BlockSpec`` grid — each
(128, 128) output tile is accumulated over K-tiles staged through VMEM and
fed to the MXU, which is the same HBM<->scratchpad schedule the CUDA kernel
expressed with threadblocks and __shared__ tiles.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and the interpret path lowers to plain HLO that the Rust
runtime runs (see /opt/xla-example/README.md).

VMEM/MXU estimate (for DESIGN.md §Perf): per grid cell the kernel holds
one (TM,K) A-slab, one (K,TN) B-slab and a (TM,TN) accumulator in VMEM:
for 512x512 f32 with TM=TN=128 that is 128*512*4 * 2 + 128*128*4 ≈ 576 KiB
— under the ~16 MiB VMEM budget, leaving room for double buffering. Every
FMA lands on the MXU via jnp.dot: arithmetic intensity = K/2 per output
element, MXU-bound for K >= 256.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output tile size (one MXU-friendly block per grid cell).
TILE = 128


def _mm_kernel(a_ref, b_ref, o_ref):
    """One (TILE, TILE) output block: full-K contraction in VMEM."""
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def _mm_pallas(a, b, tile: int):
    """C = A @ B with a Pallas grid over (tile, tile) output blocks."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert m % tile == 0 and n % tile == 0, "shapes must be tile-aligned"
    grid = (m // tile, n // tile)
    return pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            # A: the full K strip for this row of output tiles.
            pl.BlockSpec((tile, k), lambda i, j: (i, 0)),
            # B: the full K strip for this column of output tiles.
            pl.BlockSpec((k, tile), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


def _mm_or_ref(a, b, tile: int):
    """Pallas when tile-aligned, jnp otherwise (odd backward shapes)."""
    m, _ = a.shape
    _, n = b.shape
    if m % tile == 0 and n % tile == 0:
        return _mm_pallas(a, b, tile)
    return jnp.matmul(a, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _mm(a, b, tile: int):
    return _mm_pallas(a, b, tile)


def _mm_fwd(a, b, tile: int):
    return _mm_pallas(a, b, tile), (a, b)


def _mm_bwd(tile: int, res, g):
    # dA = g @ B^T, dB = A^T @ g — the backward pass rides the same Pallas
    # kernel (interpret-mode pallas_call has no built-in reverse AD).
    a, b = res
    return _mm_or_ref(g, b.T, tile), _mm_or_ref(a.T, g, tile)


_mm.defvjp(_mm_fwd, _mm_bwd)


@functools.partial(jax.jit, static_argnames=("tile",))
def matmul_tiled(a, b, tile: int = TILE):
    """C = A @ B via the Pallas tiled kernel (differentiable).

    Shapes must be multiples of ``tile`` (the AOT artifacts use 512x512;
    the hypothesis suite sweeps smaller multiples).
    """
    return _mm(a, b, tile)


def _vecadd_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


@jax.jit
def vecadd(a, b):
    """Element-wise add as a (trivial) Pallas kernel — used so even the
    simplest artifact exercises the Pallas lowering path."""
    return pl.pallas_call(
        _vecadd_kernel,
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.float32),
        interpret=True,
    )(a, b)
