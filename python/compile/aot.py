"""AOT lowering: JAX/Pallas → HLO text artifacts for the Rust runtime.

Run once at build time (``make artifacts``); Python never touches the
request path. HLO *text* is the interchange format — jax >= 0.5 emits
protos with 64-bit instruction ids that the xla crate's xla_extension
0.5.1 rejects, while the text parser reassigns ids cleanly (see
/opt/xla-example/README.md and DESIGN.md).

Usage: ``python -m compile.aot --out-dir ../artifacts``
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import matmul_tiled as ker


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


N = 1 << 20  # paper §6.2: 1M-element vector workloads
MM = 512  # matmul size (shape-reduced from the paper's 1024², DESIGN.md E2)


def artifacts():
    """name -> (function, example args). Each becomes <name>.hlo.txt."""
    return {
        "vecadd": (lambda a, b: (ker.vecadd(a, b),), [f32(N), f32(N)]),
        "saxpy": (lambda a, x, y: (a * x + y,), [f32(), f32(N), f32(N)]),
        "matmul": (
            lambda a, b: (ker.matmul_tiled(a, b),),
            [f32(MM, MM), f32(MM, MM)],
        ),
        "reduction": (lambda x: (jnp.sum(x),), [f32(N)]),
        "nn_layer": (
            lambda x, w, b: (model.nn_layer(x, w, b),),
            [
                f32(model.LAYER_B, model.LAYER_D),
                f32(model.LAYER_D, model.LAYER_H),
                f32(model.LAYER_H),
            ],
        ),
        "mlp_train_step": (
            model.mlp_train_step,
            [
                f32(model.MLP_D, model.MLP_H),
                f32(model.MLP_H),
                f32(model.MLP_H),
                f32(),
                f32(model.MLP_B, model.MLP_D),
                f32(model.MLP_B),
                f32(),
            ],
        ),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="build a single artifact")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for name, (fn, specs) in artifacts().items():
        if args.only and name != args.only:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
