#!/usr/bin/env python3
"""Bench trend gate: fail CI when the dispatch-speedup section of
BENCH_e2.json regresses by more than 20% wall-clock vs the previous
artifact (ROADMAP "Bench CI trajectory").

Usage: bench_trend.py PREV_JSON CURR_JSON [--threshold 0.20]

Exits 0 when there is no previous artifact (first run / expired
retention), when the sections are comparable, or when the current run is
faster; exits 1 on a regression beyond the threshold.
"""

import json
import os
import sys


def load(path):
    with open(path) as f:
        return json.load(f)


def main(argv):
    if len(argv) < 3:
        print(__doc__)
        return 2
    prev_path, curr_path = argv[1], argv[2]
    threshold = 0.20
    if "--threshold" in argv:
        threshold = float(argv[argv.index("--threshold") + 1])

    if not os.path.exists(prev_path):
        print(f"no previous artifact at {prev_path}; skipping trend check")
        return 0
    prev, curr = load(prev_path), load(curr_path)

    failures = []
    for section, key in [
        ("dispatch", "par_wall_s"),
        ("streams", "overlapped_s"),
        # API v2 handle churn: gates regressions in stream/event
        # create-destroy + reclamation (slot-table reuse).
        ("handles", "churn_s"),
        # Delta-state engine (BENCH_e7): gate the incremental/full *byte*
        # ratio — deterministic, unlike the sub-millisecond smoke-mode
        # wall time, which would flag runner jitter. A growing ratio
        # means deltas capture more than the dirtied fraction. Sections
        # absent from a given artifact are skipped, so one gate script
        # serves all bench files.
        ("delta", "ratio"),
        # Cross-shard atomics (BENCH_e8): gate the journal op count —
        # deterministic (threads x atomics per thread). Growth means the
        # protocol started journaling redundantly (e.g. double-committing
        # across pauses); wall times are printed but not gated.
        ("atomics", "journal_ops"),
        # Fault plane (BENCH_e9): gate the *fault-free* sharded wall
        # clock — the injection hooks and health checks sit on the hot
        # path and must stay unmeasurable when no plan is armed.
        # Recovery times are printed but not gated (they include the
        # deliberate retry backoff).
        ("fault", "fault_free_s"),
        # Tiered JIT (BENCH_e4): gate the unarmed launch path — with the
        # background compiler running but no kernel hot, the per-launch
        # tiering cost is one relaxed generation load plus one relaxed
        # profile increment and must stay unmeasurable. Steady-state
        # tier-1/tier-2 wall clocks are checked intra-artifact below.
        ("tiering", "unarmed_launch_s"),
        # Observability plane (BENCH_e2 `trace`): gate the *disarmed*
        # launch path — tracing off is the default, and every
        # instrumentation site must cost one relaxed atomic load, so any
        # slowdown here is a lock or allocation that leaked onto the hot
        # path. Armed ring-write and export costs are printed by the
        # bench but not trend-gated (they scale with ring capacity).
        ("trace", "disarmed_launch_s"),
        # Static analyzer (BENCH_e4 `analyze`): gate the load-time cost
        # per kernel — the affine engine runs once per (module, kernel)
        # and must stay cheap enough to leave on by default. The per-launch
        # pre-flight gate (Warn vs Off) is printed by the bench but not
        # trend-gated: at micro-launch scale it sits inside runner jitter.
        ("analyze", "analyze_us_per_kernel"),
        # AOT / translation cache (BENCH_e4 `aot`): gate the launch path
        # with *no* disk cache configured — the common case. The cache
        # plumbing adds one Option check on the miss path and nothing on
        # the memo fast path, so the disarmed-cache cost must not move.
        # The cold/warm/fat-blob first-launch ordering is checked
        # intra-artifact below.
        ("aot", "nocache_launch_s"),
    ]:
        p = prev.get(section, {}).get(key)
        c = curr.get(section, {}).get(key)
        if p is None or c is None:
            print(f"{section}.{key}: missing in prev or curr; skipping")
            continue
        ratio = c / p if p > 0 else 1.0
        verdict = "REGRESSION" if ratio > 1.0 + threshold else "ok"
        print(f"{section}.{key}: prev {p:.6f}s -> curr {c:.6f}s ({ratio:.2f}x) {verdict}")
        if ratio > 1.0 + threshold:
            failures.append(f"{section}.{key} slowed {ratio:.2f}x (> {1 + threshold:.2f}x)")

    # Intra-artifact invariant (BENCH_e4): tier-2 code must beat tier-1 in
    # steady state on the strength-reduction bench kernel — the whole point
    # of the optimizing mid-end. Checked on the *current* artifact alone,
    # so it fails even on the first run of a regressed build.
    tiering = curr.get("tiering", {})
    t1, t2 = tiering.get("tier1_steady_s"), tiering.get("tier2_steady_s")
    if t1 is not None and t2 is not None:
        verdict = "ok" if t2 < t1 else "REGRESSION"
        print(f"tiering: tier1 {t1:.6f}s vs tier2 {t2:.6f}s ({t1 / t2:.2f}x) {verdict}")
        if t2 >= t1:
            failures.append(f"tier-2 steady state ({t2:.6f}s) not faster than tier-1 ({t1:.6f}s)")

    # Intra-artifact invariants (BENCH_e4 `aot`): warm starts must beat the
    # cold JIT path — a fat-blob-seeded module launches with zero
    # translation work and a warm disk cache replaces lowering with one
    # file read + decode, so both first-launch tiers sit strictly below
    # the cold tier or the artifact pipeline is broken. Likewise batched
    # recording (one graph lock for N nodes) must beat N looped records.
    aot = curr.get("aot", {})
    cold = aot.get("cold_first_launch_s")
    for name, key in [("fat-blob", "fatblob_first_launch_s"), ("warm-disk", "warm_disk_first_launch_s")]:
        warm = aot.get(key)
        if cold is None or warm is None:
            continue
        verdict = "ok" if warm < cold else "REGRESSION"
        print(f"aot: {name} first launch {warm:.6f}s vs cold {cold:.6f}s ({cold / warm:.2f}x) {verdict}")
        if warm >= cold:
            failures.append(f"{name} first launch ({warm:.6f}s) not below cold JIT ({cold:.6f}s)")
    batched, looped = aot.get("batched_record_s"), aot.get("looped_record_s")
    if batched is not None and looped is not None:
        verdict = "ok" if batched < looped else "REGRESSION"
        print(f"aot: batched record {batched:.6f}s vs looped {looped:.6f}s ({looped / batched:.2f}x) {verdict}")
        if batched >= looped:
            failures.append(f"batched record ({batched:.6f}s) not below looped ({looped:.6f}s)")

    if failures:
        print("bench trend check FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("bench trend check passed")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
